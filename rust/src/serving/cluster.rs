//! Topology-routed multi-instance serving with prefill/decode
//! disaggregation, elastic autoscaling, and instance-failure recovery.
//!
//! PR 2's batcher simulates one isolated instance; this module scales
//! it to a cluster whose *shape* the fabric decides — the paper's
//! claim at serving level. N batcher instances are placed on
//! [`Topology`] devices, a front-end [`Router`] assigns arrivals under
//! a pluggable [`RoutePolicy`], and the cluster runs in one of two
//! modes:
//!
//! - **Colocated** — every instance is a full continuous batcher
//!   (prefill + decode interleaved), the classic deployment. Long
//!   prompts stall decode: the iteration that admits a prompt pays
//!   its prefill inline, so every in-flight sequence on that instance
//!   sees the stall in its TPOT.
//! - **Disaggregated** — a prefill pool and a decode pool
//!   (DistServe/Splitwise-style). Prefill instances emit the first
//!   token, then the sequence's KV pages migrate to a decode instance
//!   chosen by least-outstanding-KV. The migration is costed from
//!   [`collectives::cost`] (`CollectiveKind::P2p`) over the *actual*
//!   fabric tier between the two devices — `LinkSpec::transfer_time`
//!   on the bottleneck link — and the pages land in the destination's
//!   two-tier `PagePool`. The transfer is staged through the decode
//!   engine (a `kv_xfer` interval on its resource): on a legacy
//!   RoCE-class fabric the copy steals decode iterations, on the
//!   supernode's pooled-memory UB fabric it is near-free. That single
//!   term decides which architecture wins — exactly the knob the
//!   paper says the supernode flips.
//!
//! ## Elasticity and failure (ISSUE 4)
//!
//! The cluster is no longer statically sized or fault-free. Each
//! instance walks a lifecycle `warm-up → serving → draining →
//! released` (or `→ crashed`):
//!
//! - **Scale up** — an [`AutoscaleConfig`] policy (queue-depth,
//!   TTFT-headroom, or scheduled; see `serving::autoscale`) asks for
//!   capacity at a fixed evaluation cadence. A new instance takes the
//!   next device from the pool and pays a *model-load warm-up*: the
//!   weight bytes crossing the fabric tier between the weight source
//!   (the lowest-index serving instance's device) and the new device,
//!   recorded as a `warmup` interval on the new engine. On the
//!   supernode fabric a 16 GiB load costs ~88 ms; on legacy RoCE it
//!   costs ~1.4 s — which is why elastic scaling holds the TTFT SLO on
//!   one fabric and not the other.
//! - **Scale down** — the least-loaded serving instance stops
//!   admission (Draining), re-dispatches its queued work through the
//!   router, migrates its resident sequences' KV pages out with the
//!   PR 3 custody protocol at the next iteration boundary (pages stay
//!   parked until the destination admits), and releases its device
//!   back to the pool once its page pool drains (a zero-length `drain`
//!   marker in the trace).
//! - **Crash** — an [`InstanceCrash`] event kills an instance
//!   mid-decode: its in-flight interval is truncated and re-tagged
//!   `crash` (lost work), every request it held is re-queued through
//!   the router with the prefix-recompute cost charged (KV on the dead
//!   device is gone, so they re-prefill), sequences that had parked KV
//!   on it restart from scratch wherever they now queue, and the
//!   autoscaler spawns a replacement immediately — crash replacement
//!   never waits for cooldowns. No request is ever lost: everything is
//!   completed or rejected exactly once (the conservation property
//!   tests inject crashes and scale-downs across the full
//!   policy × mode × seed grid).
//!
//! Crash targeting is *ordinal*: `InstanceCrash::instance` selects the
//! n-th (mod size) member of the serving set at crash time, because an
//! absolute index races against elastic churn — the named instance may
//! long since have been drained and released.
//!
//! ## Page custody during migration
//!
//! A migrating sequence's pages stay **parked** in the source
//! instance's pool until the destination admits it (allocates its
//! pages there); only then does the source release. Parked pages are
//! real backpressure: a clogged decode pool keeps prefill pools full,
//! which stalls prefill admission instead of silently dropping
//! requests. No page is ever freed twice or leaked across the move —
//! `rust/tests/property_kvcache.rs` model-checks the invariant and
//! [`simulate_cluster`] asserts every non-crashed pool drains at the
//! end of a run.
//!
//! ## Reuse
//!
//! Admission goes through the shared [`plan_refill`] core, iteration
//! latency through the shared [`CostModel`], and per-instance busy
//! intervals (prefill / decode / `kv_xfer` / `warmup` / `crash` /
//! `drain`) compose into one `sim::Trace` (CSR-indexed or streaming,
//! per `ClusterConfig::trace_mode`), so the whole cluster
//! report answers every fleet-wide question (TTFT/TPOT/goodput
//! percentiles, utilization, windowed busy) through the standard
//! `ServingReport` machinery, and [`cluster_rate_sweep`] fans the
//! max-QPS-under-SLO search across `sim::sweep` workers.

use crate::collectives;
use crate::faults::{FaultPlan, RetryPolicy};
use crate::graph::CollectiveKind;
use crate::hyperoffload::kvcache::KvCacheConfig;
use crate::hyperoffload::policy::OffloadPolicy;
use crate::hyperoffload::prefix::{
    PrefixCacheConfig, PrefixKey, PrefixOp, PrefixSegment, PrefixStore, PrefixTier,
};
use crate::serving::autoscale::{AutoscaleConfig, AutoscalePolicy, ScaleObservation, ScalingPolicy};
use crate::serving::batcher::{plan_refill, CostModel};
use crate::serving::memory::{MemoryPolicy, ServingMemory};
use crate::serving::metrics::{
    max_qps_under_slo, OperatingPoint, RequestOutcome, ServingReport, Slo,
};
use crate::serving::router::{CandidateLoad, RoutePolicy, Router};
use crate::serving::workload::{
    agentic_multiturn, diurnal_two_tenant, AgenticWorkload, ArrivalProcess, LengthDist, Request,
    WorkloadConfig,
};
use crate::sim::sink::OpenIv;
use crate::sim::{tags, ResourceId, TraceCollector, TraceMode};
use crate::supernode::{DeviceId, Fleet, Topology};
use crate::util::stats::Percentiles;
use std::collections::{BTreeSet, VecDeque};

/// What one placed instance does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceRole {
    /// Full continuous batcher: prefill + decode interleaved.
    Colocated,
    /// Prefill pool member: admits prompts, emits the first token,
    /// hands the KV pages to a decode instance.
    Prefill,
    /// Decode pool member: receives migrated KV, decodes to completion.
    Decode,
}

/// One instance of the cluster: a role on a device with a slot count.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    pub device: DeviceId,
    pub role: InstanceRole,
    /// Concurrent sequences this instance batches.
    pub slots: usize,
}

/// Lifecycle of an instance under elasticity and failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum InstanceState {
    /// Loading weights over the fabric; not yet admitting.
    WarmingUp,
    /// Admitting and serving work.
    Serving,
    /// Scale-down in progress: admission stopped, resident KV
    /// migrating out under the custody protocol.
    Draining,
    /// Cleanly drained; device returned to the pool.
    Released,
    /// Killed by an [`InstanceCrash`]; its KV pages are gone.
    Crashed,
}

/// Failure injection: kill one live instance at `time`.
///
/// `instance` is *ordinal*, not absolute: it selects the
/// `instance mod |serving|`-th member of the serving set at crash time
/// (falling back to warming/draining instances if nothing is serving).
/// Absolute indices would race against elastic churn — the instance
/// they name may already have been drained and released.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceCrash {
    pub time: f64,
    pub instance: usize,
}

/// External device supplier for co-scheduled runs (ISSUE 5): when the
/// serving cluster shares its supernode with another tenant, scale-ups
/// lease devices from a broker instead of a private pool, and cleanly
/// drained devices go back to it. `hypermpmd::coschedule::LeaseBroker`
/// implements this; standalone runs use [`NullLessor`], which keeps
/// the PR 4 `AutoscaleConfig::device_pool` semantics bit-identical.
pub trait DeviceLessor {
    /// Try to obtain one device for a scale-up. Implementations record
    /// unmet demand on failure — that signal is what triggers a
    /// preemption of the co-tenant.
    fn lease(&mut self) -> Option<DeviceId>;
    /// Offer a cleanly released device back. Returns `false` when the
    /// lessor does not manage devices (the cluster then returns it to
    /// its private `device_pool`).
    fn give_back(&mut self, dev: DeviceId) -> bool;
}

/// The no-op lessor of a standalone cluster: never supplies a device,
/// never accepts one back.
pub struct NullLessor;

impl DeviceLessor for NullLessor {
    fn lease(&mut self) -> Option<DeviceId> {
        None
    }

    fn give_back(&mut self, _dev: DeviceId) -> bool {
        false
    }
}

/// A multi-instance serving deployment on a topology.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub topology: Topology,
    pub instances: Vec<InstanceSpec>,
    /// Max tokens per sequence, prompt + output.
    pub max_seq: usize,
    /// Per-instance iteration cost model (all instances identical).
    pub cost: CostModel,
    pub policy: MemoryPolicy,
    /// DRAM-pool page capacity per instance (ignored under `NoOffload`).
    pub pool_pages: usize,
    pub max_preemptions: u32,
    /// Front-end arrival routing policy.
    pub route: RoutePolicy,
    /// Elastic autoscaling of the scaled role (colocated instances, or
    /// the decode pool in disaggregated mode). `None` = static cluster.
    pub autoscale: Option<AutoscaleConfig>,
    /// Crash events to inject, any order (sorted by time internally).
    pub failures: Vec<InstanceCrash>,
    /// Fabric fault schedule (ISSUE 6): transfers *dispatched* inside
    /// a degrade window are priced over the degraded fabric (in-flight
    /// transfers keep their quote). `FaultPlan::empty()` keeps every
    /// path bit-identical to the fault-free code.
    pub faults: FaultPlan,
    /// Retry/hedging policy for migrations priced over a degraded
    /// link. `None` = dispatch at whatever the fabric costs.
    pub retry: Option<RetryPolicy>,
    /// Fleet-wide prefix cache for agentic multi-turn workloads
    /// (ISSUE 7). `None` keeps every path bit-identical to the
    /// cache-less cluster.
    pub prefix: Option<PrefixCacheConfig>,
    /// Trace representation of the run: indexed (full log, every
    /// structural query) or streaming (accumulators only — city-scale
    /// fleets in bounded memory). Summary reports are bit-identical
    /// between the two.
    pub trace_mode: TraceMode,
    /// The fleet this cluster's devices live in (ISSUE 9). `None` —
    /// and any single-pool fleet — prices every transfer on
    /// `topology`, bit-identical to the pre-fleet cluster. A
    /// multi-pool fleet re-prices cross-pool P2p transfers (KV
    /// migrations, warm-up loads, prefix fetches) on the
    /// inter-supernode link.
    pub fleet: Option<Fleet>,
    /// With a multi-pool fleet: `true` keeps KV handoffs inside the
    /// source's supernode whenever a same-pool destination is serving
    /// (crossing the DCN is a last resort); `false` is the naive
    /// placement baseline that load-balances blindly across pools.
    /// Ignored without a multi-pool fleet.
    pub fleet_aware_placement: bool,
}

impl ClusterConfig {
    /// Typed builder over the required knobs; everything else
    /// defaults to the plain static cluster (no offload, no
    /// autoscaler, no faults, no prefix cache). The struct stays
    /// plainly constructible — the builder just spares call sites
    /// from spelling out `None`/empty for every optional subsystem.
    pub fn builder(
        topology: Topology,
        instances: Vec<InstanceSpec>,
        cost: CostModel,
    ) -> ClusterConfigBuilder {
        ClusterConfigBuilder {
            cfg: ClusterConfig {
                topology,
                instances,
                max_seq: 4096,
                cost,
                policy: MemoryPolicy::NoOffload,
                pool_pages: 0,
                max_preemptions: 4,
                route: RoutePolicy::LeastOutstandingKv,
                autoscale: None,
                failures: vec![],
                faults: FaultPlan::empty(),
                retry: None,
                prefix: None,
                trace_mode: TraceMode::Indexed,
                fleet: None,
                fleet_aware_placement: true,
            },
        }
    }
}

/// Builder returned by [`ClusterConfig::builder`]; each setter
/// overrides one default, `build` hands the config back.
#[derive(Debug, Clone)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    pub fn max_seq(mut self, max_seq: usize) -> Self {
        self.cfg.max_seq = max_seq;
        self
    }

    pub fn policy(mut self, policy: MemoryPolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    pub fn pool_pages(mut self, pool_pages: usize) -> Self {
        self.cfg.pool_pages = pool_pages;
        self
    }

    pub fn max_preemptions(mut self, max_preemptions: u32) -> Self {
        self.cfg.max_preemptions = max_preemptions;
        self
    }

    pub fn route(mut self, route: RoutePolicy) -> Self {
        self.cfg.route = route;
        self
    }

    pub fn autoscale(mut self, autoscale: AutoscaleConfig) -> Self {
        self.cfg.autoscale = Some(autoscale);
        self
    }

    pub fn failures(mut self, failures: Vec<InstanceCrash>) -> Self {
        self.cfg.failures = failures;
        self
    }

    pub fn faults(mut self, faults: FaultPlan) -> Self {
        self.cfg.faults = faults;
        self
    }

    pub fn retry(mut self, retry: RetryPolicy) -> Self {
        self.cfg.retry = Some(retry);
        self
    }

    pub fn prefix(mut self, prefix: PrefixCacheConfig) -> Self {
        self.cfg.prefix = Some(prefix);
        self
    }

    pub fn trace_mode(mut self, trace_mode: TraceMode) -> Self {
        self.cfg.trace_mode = trace_mode;
        self
    }

    pub fn fleet(mut self, fleet: Fleet) -> Self {
        self.cfg.fleet = Some(fleet);
        self
    }

    pub fn fleet_aware_placement(mut self, aware: bool) -> Self {
        self.cfg.fleet_aware_placement = aware;
        self
    }

    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

/// Everything a cluster run produced: the standard serving report
/// (fleet-wide outcomes + the composed per-instance trace) plus the
/// migration ledger and the elasticity/failure ledger.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub serving: ServingReport,
    /// Prefill → decode KV handoffs plus drain/crash re-dispatches.
    pub kv_migrations: u64,
    /// KV bytes moved across the fabric.
    pub kv_bytes_migrated: f64,
    /// Total fabric time spent on KV migrations, seconds.
    pub kv_xfer_time: f64,
    /// Completions per instance (index = instance = trace resource).
    pub per_instance_completed: Vec<usize>,
    /// Instances killed by failure injection.
    pub crashes: u64,
    /// Requests re-queued out of crashed instances (re-prefilled).
    pub crash_requeues: u64,
    /// Voluntary scale-up actions (crash replacements included).
    pub scale_ups: u64,
    /// Voluntary scale-down (drain) actions.
    pub scale_downs: u64,
    /// KV handoffs specifically caused by drains.
    pub drain_migrations: u64,
    /// Total model-load transfer time paid by scale-ups, seconds.
    pub warmup_time: f64,
    /// KV migrations parked and re-routed by the retry policy because
    /// their priced transfer exceeded the timeout (ISSUE 6).
    pub retries_scheduled: u64,
    /// Migrations steered away from a degraded destination by hedging.
    pub hedged: u64,
    /// Σ over instances of (death-or-makespan − birth): the
    /// provisioning cost the autoscaler is minimizing.
    pub instance_seconds: f64,
    /// High-water mark of simultaneously held devices.
    pub peak_instances: usize,
    /// Device of each trace resource (index = instance = resource), so
    /// per-instance intervals can be mapped back onto physical devices
    /// — the co-scheduling conservation tests overlay these with the
    /// training tenant's intervals.
    pub instance_devices: Vec<DeviceId>,
    /// Devices still held by live (serving/warming/draining) instances
    /// when the run ended.
    pub held_devices_at_end: Vec<DeviceId>,
    /// Devices lost to crashes (never returned to any pool or broker).
    pub crashed_devices: Vec<DeviceId>,
    /// Fresh admissions that reused at least one cached prefix run.
    pub prefix_hits: u64,
    /// Fresh admissions that found nothing reusable.
    pub prefix_misses: u64,
    /// Prompt tokens served from the prefix cache instead of prefill.
    pub prefix_hit_tokens: u64,
    /// Prompt tokens of every fresh admission (the ratio denominator).
    pub prefix_prompt_tokens: u64,
    /// Prompt tokens actually prefilled (cache misses + segments where
    /// recompute beat the fetch price).
    pub prefix_recomputed_tokens: u64,
    /// Engine seconds stalled fetching cached runs over the fabric.
    pub prefix_fetch_time: f64,
    /// Background DMA seconds pricing tier demotions (HBM → pool →
    /// host); not engine-blocking.
    pub prefix_demote_time: f64,
    /// Cached runs promoted (back) into an admitting instance's HBM.
    pub prefix_promotions: u64,
    /// Cached runs demoted one tier by LRU pressure.
    pub prefix_demotions: u64,
    /// Cached runs evicted off the end of the tier chain.
    pub prefix_evictions: u64,
}

impl ClusterReport {
    pub fn completed(&self) -> usize {
        self.serving.completed()
    }

    /// Condense the run into a sweep row (fleet-wide percentiles).
    pub fn operating_point(&self, rate: f64, slo: &Slo) -> OperatingPoint {
        self.serving.operating_point(rate, slo)
    }

    /// Fraction of fresh-admission prompt tokens that were actually
    /// prefilled. 1.0 without a prefix store (everything recomputes);
    /// the agentic gate drives this toward 0 on the supernode fabric.
    pub fn tokens_recomputed_ratio(&self) -> f64 {
        if self.prefix_prompt_tokens == 0 {
            1.0
        } else {
            self.prefix_recomputed_tokens as f64 / self.prefix_prompt_tokens as f64
        }
    }

    /// Fraction of fresh-admission prompt tokens served from cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_prompt_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.prefix_prompt_tokens as f64
        }
    }

    /// The cluster-level summary rows: the fleet-wide serving rows
    /// plus the migration, elasticity, and prefix-cache ledgers. Every
    /// bench/example emission of a cluster run flows through this, so
    /// the key set can't drift between consumers.
    pub fn summary_kv(&self) -> Vec<(String, f64)> {
        let mut kv = self.serving.summary_kv();
        let mut push = |k: &str, v: f64| kv.push((k.to_string(), v));
        push("kv_migrations", self.kv_migrations as f64);
        push("kv_bytes_migrated", self.kv_bytes_migrated);
        push("kv_xfer_time", self.kv_xfer_time);
        push("crashes", self.crashes as f64);
        push("crash_requeues", self.crash_requeues as f64);
        push("scale_ups", self.scale_ups as f64);
        push("scale_downs", self.scale_downs as f64);
        push("warmup_time", self.warmup_time);
        push("instance_seconds", self.instance_seconds);
        push("peak_instances", self.peak_instances as f64);
        push("prefix_hit_rate", self.prefix_hit_rate());
        push("tokens_recomputed_ratio", self.tokens_recomputed_ratio());
        push("prefix_fetch_time", self.prefix_fetch_time);
        push("prefix_promotions", self.prefix_promotions as f64);
        push("prefix_demotions", self.prefix_demotions as f64);
        push("prefix_evictions", self.prefix_evictions as f64);
        kv
    }
}

/// Route the inherent rows through the shared bench-emission trait
/// (the inherent method stays for direct callers; inherent methods
/// take precedence, so this delegation does not recurse).
impl crate::util::summary::SummaryKv for ClusterReport {
    fn summary_kv(&self) -> Vec<(String, f64)> {
        ClusterReport::summary_kv(self)
    }
}

// ---- internal state ---------------------------------------------------

#[derive(Debug, Clone)]
struct Queued {
    req: Request,
    /// Raw prompt for fresh requests; clamped prompt for migrated and
    /// preempted re-queues (admission clamps via `plan_refill`).
    prompt_len: usize,
    /// Tokens already produced (≥1 for a migrated sequence; reset to 0
    /// when a crash destroys the KV and forces a re-prefill).
    produced: usize,
    first_token: Option<f64>,
    preemptions: u32,
    /// Instance still parking this sequence's KV pages, if migrating.
    kv_src: Option<usize>,
}

#[derive(Debug, Clone)]
struct ActiveSeq {
    req: Request,
    prompt_len: usize,
    produced: usize,
    admitted_at: f64,
    first_token: Option<f64>,
    preemptions: u32,
}

impl ActiveSeq {
    fn ctx(&self) -> usize {
        self.prompt_len + self.produced
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Work {
    Iteration,
    Ingest,
    /// Model-load transfer of a warming-up instance.
    Warmup,
}

#[derive(Debug)]
struct IngestJob {
    entry: Queued,
    /// Fabric transfer time, fixed when the migration was issued.
    xfer: f64,
}

/// A migration parked by the [`RetryPolicy`]: the pages stay in
/// custody at `entry.kv_src` until the re-route dispatches (or
/// rejects) it at `due`.
#[derive(Debug)]
struct RetryEntry {
    /// When the re-route fires: park time + timeout + backoff·attempts.
    due: f64,
    entry: Queued,
    /// Attempts spent, counting the dispatch that parked this entry.
    attempts: u32,
    drain: bool,
    /// The slow destination this retry is hedging away from.
    exclude: usize,
}

#[derive(Debug)]
struct Instance {
    role: InstanceRole,
    device: DeviceId,
    mem: ServingMemory,
    queue: VecDeque<Queued>,
    /// Pending KV ingests; the transfer occupies this engine,
    /// serialized with its iterations.
    ingest: VecDeque<IngestJob>,
    active: Vec<Option<ActiveSeq>>,
    work_end: Option<(f64, Work)>,
    cur_ctx_tokens: usize,
    state: InstanceState,
    /// When this instance started holding its device.
    born: f64,
    /// When it stopped (released or crashed); `None` = held to the end.
    died: Option<f64>,
    /// Handle to the open trace interval of the in-flight work, so a
    /// crash can truncate it at the instant of death. Must be closed
    /// (or truncated) before being dropped so the streaming sink can
    /// fold and free the slot.
    cur_iv: Option<OpenIv>,
}

impl Instance {
    fn new(spec: &InstanceSpec, cfg: &ClusterConfig) -> Self {
        assert!(spec.slots >= 1, "instance needs at least one slot");
        Self {
            role: spec.role,
            device: spec.device,
            mem: ServingMemory::new(
                &cfg.cost.kv,
                cfg.cost.offload_frac,
                cfg.policy,
                cfg.pool_pages,
            ),
            queue: VecDeque::new(),
            ingest: VecDeque::new(),
            active: (0..spec.slots).map(|_| None).collect(),
            work_end: None,
            cur_ctx_tokens: 0,
            state: InstanceState::Serving,
            born: 0.0,
            died: None,
            cur_iv: None,
        }
    }

    fn active_count(&self) -> usize {
        self.active.iter().filter(|s| s.is_some()).count()
    }

    /// Routing load signal: KV pages held (incl. parked) plus pages
    /// the queued requests will need at admission plus pages riding
    /// in-flight ingests. Without the inbound term, simultaneous
    /// migrations from one prefill iteration would all see identical
    /// loads and pile onto the lowest-index decode instance.
    fn outstanding_kv(&self) -> usize {
        let pages = |prompt_len: usize, produced: usize| {
            self.mem.pages_for(prompt_len + produced.max(1))
        };
        let queued: usize = self
            .queue
            .iter()
            .map(|q| pages(q.prompt_len, q.produced))
            .sum();
        let inbound: usize = self
            .ingest
            .iter()
            .map(|j| pages(j.entry.prompt_len, j.entry.produced))
            .sum();
        self.mem.pool.hbm_used() + self.mem.pool.pool_used() + queued + inbound
    }
}

#[derive(Debug, Default)]
struct Stats {
    outcomes: Vec<RequestOutcome>,
    rejected: u64,
    preemptions: u64,
    decoded_tokens: u64,
    prefill_tokens: u64,
    trace: TraceCollector,
    kv_migrations: u64,
    kv_bytes: f64,
    kv_xfer_time: f64,
    per_instance_completed: Vec<usize>,
    crashes: u64,
    crash_requeues: u64,
    scale_ups: u64,
    scale_downs: u64,
    drain_migrations: u64,
    warmup_time: f64,
    retries_scheduled: u64,
    hedged: u64,
    prefix_hits: u64,
    prefix_misses: u64,
    prefix_hit_tokens: u64,
    prefix_prompt_tokens: u64,
    prefix_recomputed_tokens: u64,
    prefix_fetch_time: f64,
    prefix_demote_time: f64,
    prefix_promotions: u64,
    prefix_demotions: u64,
    prefix_evictions: u64,
    /// (sequence, source instance) page handoffs pending release —
    /// drained at the cluster level after every event.
    handoffs: Vec<(u64, usize)>,
    /// Instances to wake after releases/migrations/requeues.
    kick: BTreeSet<usize>,
}

/// Zero-length tagged marker on instance `k`'s trace track (free
/// variant of [`ClusterSim::push_marker`] for split-borrow contexts).
fn push_marker_stats(stats: &mut Stats, k: usize, t: f64, tag: u64) {
    stats.trace.push(ResourceId(k), t, t, tag);
}

/// The multi-pool fleet of a config, if any. Single-pool fleets price
/// on the bare topology (the degenerate case stays bit-identical).
fn multi_pool_fleet(cfg: &ClusterConfig) -> Option<&Fleet> {
    cfg.fleet.as_ref().filter(|f| f.pool_count() > 1)
}

/// Clean (fault-free) P2p price between two devices: fleet-aware —
/// cross-pool pairs ride the inter-supernode link — and otherwise the
/// exact pre-fleet `collectives::cost` call.
fn p2p_clean(cfg: &ClusterConfig, a: DeviceId, b: DeviceId, bytes: f64) -> f64 {
    match multi_pool_fleet(cfg) {
        Some(fleet) => collectives::cost_fleet(fleet, CollectiveKind::P2p, bytes, &[a, b]).time,
        None => collectives::cost(&cfg.topology, CollectiveKind::P2p, bytes, &[a, b]).time,
    }
}

/// P2p transfer time between two devices quoted at dispatch time `t`,
/// honoring the fault plan — the same quote-at-dispatch rule KV
/// migrations use.
fn p2p_at(cfg: &ClusterConfig, t: f64, a: DeviceId, b: DeviceId, bytes: f64) -> f64 {
    if cfg.faults.degraded_at(t) {
        match multi_pool_fleet(cfg) {
            Some(fleet) => {
                let eff = cfg.faults.effective_fleet(fleet, t);
                collectives::cost_fleet(&eff, CollectiveKind::P2p, bytes, &[a, b]).time
            }
            None => {
                let eff = cfg.faults.effective_topology(&cfg.topology, t);
                collectives::cost(&eff, CollectiveKind::P2p, bytes, &[a, b]).time
            }
        }
    } else {
        p2p_clean(cfg, a, b, bytes)
    }
}

/// Price fetching one cached segment into instance `k` at time `t`:
/// free from local HBM, a fabric P2p from a remote instance's HBM, a
/// pooled-memory stream (plus the P2p hop when remote) from the pool
/// tier, and a host-bandwidth stream from host memory.
fn segment_fetch_time(
    cfg: &ClusterConfig,
    pcfg: &PrefixCacheConfig,
    devices: &[DeviceId],
    k: usize,
    t: f64,
    seg: &PrefixSegment,
) -> f64 {
    let bytes = seg.tokens as f64 * cfg.cost.kv.kv_bytes_per_token as f64;
    match seg.tier {
        PrefixTier::Hbm => {
            if seg.home == k {
                0.0
            } else {
                p2p_at(cfg, t, devices[seg.home], devices[k], bytes)
            }
        }
        PrefixTier::Pool => {
            let stream = bytes / cfg.cost.kv.pool_bw;
            if seg.home == k {
                stream
            } else {
                stream + p2p_at(cfg, t, devices[seg.home], devices[k], bytes)
            }
        }
        PrefixTier::Host => bytes / pcfg.host_bw,
    }
}

/// Record the store's placement changes: trace markers, counters, and
/// the background DMA price of each demotion.
fn apply_prefix_ops(cfg: &ClusterConfig, stats: &mut Stats, k: usize, t: f64, ops: &[PrefixOp]) {
    let Some(pcfg) = cfg.prefix.as_ref() else {
        return;
    };
    let page_bytes = cfg.cost.kv.tokens_per_page as f64 * cfg.cost.kv.kv_bytes_per_token as f64;
    for op in ops {
        match op {
            PrefixOp::Promote { .. } => {
                stats.prefix_promotions += 1;
                push_marker_stats(stats, k, t, tags::PREFIX_PROMOTE);
            }
            PrefixOp::Demote { pages, to, .. } => {
                stats.prefix_demotions += 1;
                let bytes = *pages as f64 * page_bytes;
                stats.prefix_demote_time += match to {
                    PrefixTier::Pool => bytes / cfg.cost.kv.pool_bw,
                    PrefixTier::Host => bytes / pcfg.host_bw,
                    PrefixTier::Hbm => 0.0,
                };
                push_marker_stats(stats, k, t, tags::PREFIX_DEMOTE);
            }
            PrefixOp::Evict { .. } => stats.prefix_evictions += 1,
        }
    }
}

/// One fresh admission against the prefix store: look up the shared
/// runs, keep each segment only when fetching beats recomputing it
/// (on legacy fabrics the remote/host price loses that race, which is
/// what collapses the cache's gain there), then commit the admission.
/// Returns `(cached_tokens, fetch_seconds)` — the caller subtracts
/// the cached tokens from the iteration's prefill and stalls it by
/// the fetch.
#[allow(clippy::too_many_arguments)]
fn prefix_admit(
    cfg: &ClusterConfig,
    store: &mut PrefixStore,
    stats: &mut Stats,
    devices: &[DeviceId],
    k: usize,
    t: f64,
    req: &Request,
    prompt_len: usize,
) -> (usize, f64) {
    let pcfg = cfg.prefix.as_ref().expect("prefix store without config");
    stats.prefix_prompt_tokens += prompt_len as u64;
    let shared = req.shared_prefix_tokens.min(prompt_len);
    if shared == 0 {
        // single-shot requests neither hit nor populate the store
        stats.prefix_misses += 1;
        stats.prefix_recomputed_tokens += prompt_len as u64;
        return (0, 0.0);
    }
    let mut cached = 0usize;
    let mut fetch = 0.0f64;
    let mut fetched_remote = false;
    let mut used: Vec<PrefixKey> = Vec::new();
    for seg in store.lookup(req.tenant, req.session, shared) {
        let xfer = segment_fetch_time(cfg, pcfg, devices, k, t, &seg);
        let recompute = seg.tokens as f64 / cfg.cost.prefill_tokens_per_s;
        if xfer < recompute {
            cached += seg.tokens;
            fetch += xfer;
            used.push(seg.key);
            if xfer > 0.0 {
                fetched_remote = true;
            }
        }
    }
    if fetched_remote {
        push_marker_stats(stats, k, t, tags::PREFIX_FETCH);
    }
    if cached > 0 {
        stats.prefix_hits += 1;
    } else {
        stats.prefix_misses += 1;
    }
    stats.prefix_hit_tokens += cached as u64;
    stats.prefix_recomputed_tokens += (prompt_len - cached) as u64;
    stats.prefix_fetch_time += fetch;
    let ops = store.admit(req.tenant, req.session, shared, prompt_len, k, &used);
    apply_prefix_ops(cfg, stats, k, t, &ops);
    (cached, fetch)
}

fn cold_order(inst: &Instance) -> Vec<u64> {
    let mut v: Vec<(f64, u64)> = inst
        .active
        .iter()
        .flatten()
        .map(|s| (s.admitted_at, s.req.id))
        .collect();
    v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    v.into_iter().map(|(_, id)| id).collect()
}

fn youngest_slot(inst: &Instance) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for (i, s) in inst.active.iter().enumerate() {
        if let Some(seq) = s {
            let better = match best {
                None => true,
                Some(b) => seq.admitted_at > b.0 || (seq.admitted_at == b.0 && i > b.1),
            };
            if better {
                best = Some((seq.admitted_at, i));
            }
        }
    }
    best.map(|b| b.1)
}

/// Evict one sequence, recompute-style: pages released, restart from
/// the queue head (it re-prefills wherever it now sits — decode
/// instances are the same hardware, specialization is scheduling).
fn preempt(inst: &mut Instance, slot: usize, max_preemptions: u32, stats: &mut Stats) {
    let seq = inst.active[slot].take().expect("preempting an empty slot");
    inst.mem.pool.release(seq.req.id);
    stats.preemptions += 1;
    let preemptions = seq.preemptions + 1;
    if preemptions > max_preemptions {
        stats.rejected += 1;
        return;
    }
    inst.queue.push_front(Queued {
        req: seq.req,
        prompt_len: seq.prompt_len,
        produced: 0,
        first_token: seq.first_token,
        preemptions,
        kv_src: None,
    });
}

fn grow_active(inst: &mut Instance, cfg: &ClusterConfig, stats: &mut Stats) {
    let mut i = 0usize;
    while i < inst.active.len() {
        let (id, need) = match &inst.active[i] {
            Some(s) => (s.req.id, inst.mem.pages_for(s.ctx())),
            None => {
                i += 1;
                continue;
            }
        };
        let have = inst.mem.pool.seq_pages(id).total();
        if need <= have {
            i += 1;
            continue;
        }
        let delta = need - have;
        let cold = cold_order(inst);
        if inst.mem.ensure_hbm_free(delta, &cold) && inst.mem.pool.try_alloc_hbm(id, delta) {
            i += 1;
            continue;
        }
        let victim = youngest_slot(inst).expect("growth requires an active sequence");
        preempt(inst, victim, cfg.max_preemptions, stats);
    }
}

/// Strict less-than over (time, event-class, index) — the total event
/// order: arrival < work-end < crash < autoscale tick < retry-due at
/// equal times, lowest instance index first among simultaneous
/// work-ends.
fn event_lt(a: (f64, u8, usize), b: (f64, u8, usize)) -> bool {
    a.0.total_cmp(&b.0)
        .then(a.1.cmp(&b.1))
        .then(a.2.cmp(&b.2))
        .is_lt()
}

// ---- the elastic cluster simulator ------------------------------------

/// The cluster DES as a *steppable process*: `next_event` peeks the
/// time of the next internal event, `process` executes exactly one
/// event (including its cross-instance quiescence drain). Standalone
/// runs ([`simulate_cluster`]) just loop; the co-scheduler
/// (`hypermpmd::coschedule`) interleaves these steps with a training
/// tenant on the shared virtual clock, mediating devices through a
/// [`DeviceLessor`] between events.
pub(crate) struct ClusterSim<'a> {
    cfg: &'a ClusterConfig,
    requests: &'a [Request],
    insts: Vec<Instance>,
    router: Router,
    stats: Stats,
    /// Entries with no routable instance yet (capacity is warming up).
    limbo: VecDeque<Queued>,
    /// Devices available for scale-ups; released devices return here.
    pool_devices: VecDeque<DeviceId>,
    entry_role: InstanceRole,
    scaled_role: InstanceRole,
    /// Time of the last voluntary scaling action (cooldown anchor).
    last_action: f64,
    recent_arrivals: VecDeque<f64>,
    /// First outcome still inside the policy lookback window.
    outcome_ptr: usize,
    peak_context: usize,
    peak_alive: usize,
    /// Failure injections sorted by (time, instance).
    failures: Vec<InstanceCrash>,
    next_arrival: usize,
    next_failure: usize,
    next_tick: Option<f64>,
    /// Virtual time of the event being processed — the dispatch
    /// timestamp fault pricing reads.
    now: f64,
    /// Migrations parked by the retry policy (class-4 events).
    retries: Vec<RetryEntry>,
    /// The fleet-wide prefix store, when `cfg.prefix` is set.
    prefix: Option<PrefixStore>,
}

impl<'a> ClusterSim<'a> {
    fn serving_ids(&self, role: InstanceRole) -> Vec<usize> {
        self.insts
            .iter()
            .enumerate()
            .filter(|(_, i)| i.role == role && i.state == InstanceState::Serving)
            .map(|(k, _)| k)
            .collect()
    }

    fn warming_count(&self, role: InstanceRole) -> usize {
        self.insts
            .iter()
            .filter(|i| i.role == role && i.state == InstanceState::WarmingUp)
            .count()
    }

    fn alive_count(&self, role: InstanceRole) -> usize {
        self.insts
            .iter()
            .filter(|i| {
                i.role == role
                    && matches!(i.state, InstanceState::Serving | InstanceState::WarmingUp)
            })
            .count()
    }

    fn candidate_loads(&self, ids: &[usize], req: &Request) -> Vec<CandidateLoad> {
        ids.iter()
            .map(|&i| CandidateLoad {
                instance: i,
                outstanding_kv_pages: self.insts[i].outstanding_kv(),
                expected_prefix_hit_pages: self.prefix.as_ref().map_or(0, |s| {
                    s.local_hit_pages(req.tenant, req.session, req.shared_prefix_tokens, i)
                }),
            })
            .collect()
    }

    /// The serving scaled-role instance with the fewest outstanding KV
    /// pages — page headroom is the only signal that matters for a KV
    /// handoff.
    fn pick_dst(&self, cands: &[usize]) -> usize {
        cands
            .iter()
            .copied()
            .min_by_key(|&i| (self.insts[i].outstanding_kv(), i))
            .expect("non-empty candidate set")
    }

    /// Same-supernode preference (ISSUE 9): with a multi-pool fleet
    /// and aware placement, a KV handoff stays inside the source's
    /// pool whenever any same-pool candidate is serving — crossing
    /// the DCN is a last resort, not a load-balancing option. The
    /// naive baseline (and every fleet-less cluster) passes the
    /// candidate set through untouched.
    fn pool_filter(&self, src_dev: DeviceId, cands: Vec<usize>) -> Vec<usize> {
        let Some(fleet) = multi_pool_fleet(self.cfg) else {
            return cands;
        };
        if !self.cfg.fleet_aware_placement {
            return cands;
        }
        let home = fleet.pool_of(src_dev);
        let same: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&c| fleet.pool_of(self.insts[c].device) == home)
            .collect();
        if same.is_empty() {
            cands
        } else {
            same
        }
    }

    /// Straggler-aware hedging: when some destination's path from the
    /// source is degraded beyond `retry.hedge`× its clean transfer
    /// time and a clean destination exists, drop the slow ones.
    fn hedge_filter(&mut self, src_dev: DeviceId, cands: Vec<usize>, bytes: f64) -> Vec<usize> {
        let Some(rp) = self.cfg.retry else {
            return cands;
        };
        if rp.hedge <= 0.0 || !self.cfg.faults.degraded_at(self.now) {
            return cands;
        }
        let mut clean = Vec::new();
        for &c in &cands {
            let dst_dev = self.insts[c].device;
            let base = p2p_clean(self.cfg, src_dev, dst_dev, bytes);
            let eff = p2p_at(self.cfg, self.now, src_dev, dst_dev, bytes);
            if eff <= rp.hedge * base {
                clean.push(c);
            }
        }
        if !clean.is_empty() {
            if clean.len() < cands.len() {
                self.stats.hedged += 1;
            }
            return clean;
        }
        cands
    }

    /// Send a migrating entry (pages parked at `entry.kv_src`) to a
    /// serving scaled-role instance; limbo it if capacity is warming
    /// up; reject it (releasing the parked pages) if it can never be
    /// served. Transfers are priced over the degraded fabric at
    /// dispatch time; the retry policy parks the entry (pages stay in
    /// custody at the source) and re-routes after a backoff instead of
    /// starting a transfer that would blow the timeout — after
    /// `max_attempts` it accepts the slow path, so no request is ever
    /// lost to a fault window.
    fn dispatch_migration(&mut self, entry: Queued, drain: bool, attempts: u32, exclude: Option<usize>) {
        let mut cands = self.serving_ids(self.scaled_role);
        if let Some(x) = exclude {
            if cands.len() > 1 {
                cands.retain(|&c| c != x);
            }
        }
        if cands.is_empty() {
            if self.warming_count(self.scaled_role) > 0 {
                self.limbo.push_back(entry);
            } else {
                if let Some(src) = entry.kv_src {
                    self.stats.handoffs.push((entry.req.id, src));
                }
                self.stats.rejected += 1;
            }
            return;
        }
        let src = entry.kv_src.expect("migration entry must have a source");
        let src_dev = self.insts[src].device;
        let ctx = entry.prompt_len + entry.produced;
        let bytes = ctx as f64 * self.cfg.cost.kv.kv_bytes_per_token as f64;
        let cands = self.pool_filter(src_dev, cands);
        let cands = self.hedge_filter(src_dev, cands, bytes);
        let dst = self.pick_dst(&cands);
        let dst_dev = self.insts[dst].device;
        let base = p2p_clean(self.cfg, src_dev, dst_dev, bytes);
        let xfer = p2p_at(self.cfg, self.now, src_dev, dst_dev, bytes);
        if let Some(rp) = self.cfg.retry {
            if xfer > rp.timeout && attempts < rp.max_attempts {
                self.stats.retries_scheduled += 1;
                self.push_marker(dst, self.now, tags::RETRY);
                self.retries.push(RetryEntry {
                    due: self.now + rp.timeout + rp.backoff * attempts as f64,
                    entry,
                    attempts: attempts + 1,
                    drain,
                    exclude: dst,
                });
                return;
            }
        }
        if xfer > base {
            // retries exhausted (or no policy): the slow transfer goes
            // out anyway, flagged in the trace
            self.push_marker(dst, self.now, tags::LINK_DEGRADE);
        }
        self.stats.kv_migrations += 1;
        self.stats.kv_bytes += bytes;
        self.stats.kv_xfer_time += xfer;
        if drain {
            self.stats.drain_migrations += 1;
        }
        self.insts[dst].ingest.push_back(IngestJob { entry, xfer });
        self.stats.kick.insert(dst);
    }

    /// Zero-length tagged marker on instance `k`'s trace track.
    fn push_marker(&mut self, k: usize, t: f64, tag: u64) {
        push_marker_stats(&mut self.stats, k, t, tag);
    }

    /// Put a pageless entry back through the front-end router.
    /// `exclude` is the slow/dead instance a retry is hedging away
    /// from (dropped only if another candidate exists).
    fn route_requeue(&mut self, entry: Queued, exclude: Option<usize>) {
        let cands = self.serving_ids(self.entry_role);
        if cands.is_empty() {
            if self.warming_count(self.entry_role) > 0 {
                self.limbo.push_back(entry);
            } else {
                // release pages still parked for this entry: a rejected
                // re-queue of a migrating sequence must not leak custody
                if let Some(src) = entry.kv_src {
                    self.stats.handoffs.push((entry.req.id, src));
                }
                self.stats.rejected += 1;
            }
            return;
        }
        let loads = self.candidate_loads(&cands, &entry.req);
        let excluded: &[usize] = match &exclude {
            Some(x) => std::slice::from_ref(x),
            None => &[],
        };
        let k = self.router.route(&entry.req, &loads, excluded);
        self.insts[k].queue.push_back(entry);
        self.stats.kick.insert(k);
    }

    fn redispatch(&mut self, entry: Queued, drain: bool) {
        if entry.kv_src.is_some() {
            self.dispatch_migration(entry, drain, 0, None);
        } else {
            self.route_requeue(entry, None);
        }
    }

    /// Retry limbo entries after capacity changed (a warm-up finished,
    /// or a crash removed the last warming instance).
    fn resolve_limbo(&mut self) {
        let pending: Vec<Queued> = self.limbo.drain(..).collect();
        for entry in pending {
            self.redispatch(entry, false);
        }
    }

    /// Scale up by one instance of the scaled role, paying the
    /// model-load warm-up transfer over the actual fabric tier. The
    /// private pool is tried first, then the lessor (which records
    /// unmet demand — the broker's preemption signal — on failure).
    fn spawn_instance(&mut self, t: f64, lessor: &mut dyn DeviceLessor) -> bool {
        let cfg = self.cfg;
        let aus = cfg.autoscale.as_ref().expect("spawn requires autoscale");
        let Some(dev) = self.pool_devices.pop_front().or_else(|| lessor.lease()) else {
            return false;
        };
        let src_dev = self
            .insts
            .iter()
            .find(|i| i.state == InstanceState::Serving)
            .map(|i| i.device)
            .unwrap_or(dev);
        // the model load pays the (possibly degraded) fabric — and on
        // a multi-pool fleet, the inter-supernode link if the weight
        // source sits in another pool
        let xfer = p2p_at(cfg, t, src_dev, dev, cfg.cost.kv.weight_bytes as f64);
        let k = self.insts.len();
        let warmup_iv = self
            .stats
            .trace
            .open(ResourceId(k), t, t + xfer, tags::WARMUP);
        self.stats.per_instance_completed.push(0);
        self.stats.warmup_time += xfer;
        self.stats.scale_ups += 1;
        self.insts.push(Instance {
            role: self.scaled_role,
            device: dev,
            mem: ServingMemory::new(
                &cfg.cost.kv,
                cfg.cost.offload_frac,
                cfg.policy,
                cfg.pool_pages,
            ),
            queue: VecDeque::new(),
            ingest: VecDeque::new(),
            active: (0..aus.slots).map(|_| None).collect(),
            work_end: Some((t + xfer, Work::Warmup)),
            cur_ctx_tokens: 0,
            state: InstanceState::WarmingUp,
            born: t,
            died: None,
            cur_iv: Some(warmup_iv),
        });
        true
    }

    /// Scale down: stop admission, re-dispatch queued work, and (at
    /// the next iteration boundary) migrate resident KV out with the
    /// custody protocol. The device is released when the pool drains.
    fn drain_instance(&mut self, k: usize, _t: f64) {
        self.insts[k].state = InstanceState::Draining;
        self.stats.scale_downs += 1;
        let q: Vec<Queued> = self.insts[k].queue.drain(..).collect();
        for e in q {
            self.redispatch(e, true);
        }
        // an in-flight ingest transfer finishes (sunk cost) and is
        // re-dispatched at completion; pending ones re-dispatch now
        let inflight = matches!(self.insts[k].work_end, Some((_, Work::Ingest)));
        let keep = usize::from(inflight).min(self.insts[k].ingest.len());
        let jobs: Vec<IngestJob> = self.insts[k].ingest.split_off(keep).into_iter().collect();
        for job in jobs {
            self.redispatch(job.entry, true);
        }
    }

    fn autoscale_tick(&mut self, t: f64, lessor: &mut dyn DeviceLessor) {
        let cfg = self.cfg;
        let aus = cfg.autoscale.as_ref().expect("tick requires autoscale");
        let serving = self.serving_ids(self.scaled_role);
        let warming = self.warming_count(self.scaled_role);
        let total_slots: usize = serving
            .iter()
            .map(|&k| self.insts[k].active.len())
            .sum::<usize>()
            + warming * aus.slots;
        let queued: usize = serving
            .iter()
            .map(|&k| self.insts[k].queue.len() + self.insts[k].ingest.len())
            .sum::<usize>()
            + self.limbo.len();
        let active: usize = serving.iter().map(|&k| self.insts[k].active_count()).sum();
        while self.outcome_ptr < self.stats.outcomes.len()
            && self.stats.outcomes[self.outcome_ptr].finish < t - aus.lookback
        {
            self.outcome_ptr += 1;
        }
        let recent_ttft_p99 = {
            let mut pct = Percentiles::new();
            for o in &self.stats.outcomes[self.outcome_ptr..] {
                pct.add(o.ttft());
            }
            if pct.is_empty() {
                None
            } else {
                Some(pct.pct(99.0))
            }
        };
        while self
            .recent_arrivals
            .front()
            .is_some_and(|&a| a < t - aus.lookback)
        {
            self.recent_arrivals.pop_front();
        }
        let obs = ScaleObservation {
            now: t,
            serving: serving.len(),
            warming,
            total_slots,
            spawn_slots: aus.slots,
            queued,
            active,
            recent_ttft_p99,
            recent_arrival_rate: self.recent_arrivals.len() as f64 / aus.lookback,
        };
        let delta = aus.policy.decide(&obs);
        let mut n = serving.len() + warming;
        match delta.cmp(&0) {
            std::cmp::Ordering::Greater => {
                if t - self.last_action < aus.up_cooldown {
                    return;
                }
                let mut spawned = false;
                for _ in 0..delta {
                    if n >= aus.max_instances || !self.spawn_instance(t, lessor) {
                        break;
                    }
                    spawned = true;
                    n += 1;
                }
                if spawned {
                    self.last_action = t;
                }
            }
            std::cmp::Ordering::Less => {
                if t - self.last_action < aus.down_cooldown {
                    return;
                }
                let mut serving = serving;
                let mut drained = false;
                for _ in 0..(-delta) {
                    if n <= aus.min_instances || serving.is_empty() {
                        break;
                    }
                    // cheapest drain first: fewest outstanding KV pages,
                    // ties toward the newest instance
                    let victim = *serving
                        .iter()
                        .min_by_key(|&&k| (self.insts[k].outstanding_kv(), std::cmp::Reverse(k)))
                        .expect("non-empty serving set");
                    serving.retain(|&x| x != victim);
                    self.drain_instance(victim, t);
                    drained = true;
                    n -= 1;
                }
                if drained {
                    self.last_action = t;
                }
            }
            std::cmp::Ordering::Equal => {}
        }
    }

    /// Kill the `sel`-th (mod size) member of the serving set:
    /// truncate in-flight work, requeue everything the victim held
    /// (prefix recompute charged), drop its KV pages, and let the
    /// autoscaler spawn a replacement.
    fn crash_instance(&mut self, sel: usize, t: f64, lessor: &mut dyn DeviceLessor) {
        let mut alive: Vec<usize> = (0..self.insts.len())
            .filter(|&k| self.insts[k].state == InstanceState::Serving)
            .collect();
        if alive.is_empty() {
            alive = (0..self.insts.len())
                .filter(|&k| {
                    matches!(
                        self.insts[k].state,
                        InstanceState::WarmingUp | InstanceState::Draining
                    )
                })
                .collect();
        }
        if alive.is_empty() {
            return;
        }
        let k = alive[sel % alive.len()];
        self.stats.crashes += 1;
        if self.insts[k].work_end.is_some() {
            if let Some(iv) = self.insts[k].cur_iv.take() {
                // the in-flight work never finishes: truncate it at the
                // instant of death and re-tag it as lost
                self.stats.trace.truncate(iv, t, tags::CRASH);
                self.stats.trace.close(iv);
            }
        } else {
            self.stats.trace.push(ResourceId(k), t, t, tags::CRASH);
        }
        let was_scaled = self.insts[k].role == self.scaled_role
            && self.insts[k].state != InstanceState::WarmingUp;
        // mark dead FIRST: no requeue below may route back onto the
        // dying instance
        self.insts[k].state = InstanceState::Crashed;
        self.insts[k].died = Some(t);
        let slots = self.insts[k].active.len();
        for slot in 0..slots {
            let Some(seq) = self.insts[k].active[slot].take() else {
                continue;
            };
            self.stats.crash_requeues += 1;
            self.route_requeue(
                Queued {
                    req: seq.req,
                    prompt_len: seq.prompt_len,
                    produced: 0,
                    first_token: seq.first_token,
                    preemptions: seq.preemptions,
                    kv_src: None,
                },
                None,
            );
        }
        let q: Vec<Queued> = self.insts[k].queue.drain(..).collect();
        for e in q {
            self.stats.crash_requeues += 1;
            self.redispatch(e, false);
        }
        let jobs: Vec<IngestJob> = self.insts[k].ingest.drain(..).collect();
        for job in jobs {
            self.stats.crash_requeues += 1;
            self.redispatch(job.entry, false);
        }
        // sequences whose pages were parked here lost their KV: they
        // restart (re-prefill) wherever they are queued now
        for i in 0..self.insts.len() {
            if i == k {
                continue;
            }
            for e in self.insts[i].queue.iter_mut() {
                if e.kv_src == Some(k) {
                    e.kv_src = None;
                    e.produced = 0;
                }
            }
            for j in self.insts[i].ingest.iter_mut() {
                if j.entry.kv_src == Some(k) {
                    j.entry.kv_src = None;
                    j.entry.produced = 0;
                }
            }
        }
        for e in self.limbo.iter_mut() {
            if e.kv_src == Some(k) {
                e.kv_src = None;
                e.produced = 0;
            }
        }
        // entries parked for a retry lose their source the same way:
        // without this, the retry would later "hand off" pages against
        // a wiped pool and resume decoding from KV that no longer exists
        for r in self.retries.iter_mut() {
            if r.entry.kv_src == Some(k) {
                r.entry.kv_src = None;
                r.entry.produced = 0;
            }
        }
        self.insts[k].mem.pool.release_all();
        // cached prefix runs homed on the dead instance are gone with
        // its HBM and pooled memory; host-tier copies survive
        if let Some(store) = self.prefix.as_mut() {
            store.invalidate_instance(k);
        }
        self.insts[k].work_end = None;
        self.insts[k].cur_iv = None;
        self.insts[k].cur_ctx_tokens = 0;
        // the autoscaler replaces a crashed serving instance right away
        // (no cooldown: failure replacement is not a voluntary action)
        if let Some(aus) = self.cfg.autoscale.as_ref() {
            if was_scaled && self.alive_count(self.scaled_role) < aus.max_instances {
                self.spawn_instance(t, lessor);
            }
        }
        self.resolve_limbo();
    }

    /// An iteration completed at `t` on instance `k`: every active
    /// sequence produced one token; finished sequences retire, finished
    /// *prefills* (and survivors on a draining instance) migrate to a
    /// serving scaled-role instance.
    fn finish_iteration(&mut self, k: usize, t: f64) {
        self.insts[k].work_end = None;
        if let Some(iv) = self.insts[k].cur_iv.take() {
            self.stats.trace.close(iv);
        }
        let draining = self.insts[k].state == InstanceState::Draining;
        let slots = self.insts[k].active.len();
        for slot in 0..slots {
            let (done, migrate) = {
                let inst = &mut self.insts[k];
                let Some(seq) = inst.active[slot].as_mut() else {
                    continue;
                };
                seq.produced += 1;
                self.stats.decoded_tokens += 1;
                if seq.first_token.is_none() {
                    seq.first_token = Some(t);
                }
                let target = seq.req.output_tokens.min(self.cfg.max_seq - seq.prompt_len);
                let done = seq.produced >= target || seq.ctx() >= self.cfg.max_seq;
                (
                    done,
                    (inst.role == InstanceRole::Prefill || draining) && !done,
                )
            };
            if migrate {
                // hand the KV pages to a serving instance; pages stay
                // parked here until the destination admits the sequence
                let seq = self.insts[k].active[slot].take().expect("slot checked above");
                self.dispatch_migration(
                    Queued {
                        req: seq.req,
                        prompt_len: seq.prompt_len,
                        produced: seq.produced,
                        first_token: seq.first_token,
                        preemptions: seq.preemptions,
                        kv_src: Some(k),
                    },
                    draining,
                    0,
                    None,
                );
            } else if done {
                let seq = self.insts[k].active[slot].take().expect("slot checked above");
                self.stats.outcomes.push(RequestOutcome {
                    id: seq.req.id,
                    tenant: seq.req.tenant,
                    arrival: seq.req.arrival,
                    first_token: seq.first_token.unwrap_or(t),
                    finish: t,
                    prompt_tokens: seq.prompt_len,
                    output_tokens: seq.produced,
                    preemptions: seq.preemptions,
                });
                self.stats.per_instance_completed[k] += 1;
                self.insts[k].mem.pool.release(seq.req.id);
                // a completed agentic turn leaves its full context in
                // the prefix store for the session's next turn;
                // single-shot requests (no shared prefix) don't insert
                if seq.req.shared_prefix_tokens > 0 {
                    let ops = self.prefix.as_mut().map(|s| {
                        s.extend(
                            seq.req.tenant,
                            seq.req.session,
                            seq.prompt_len + seq.produced,
                            k,
                        )
                    });
                    if let Some(ops) = ops {
                        apply_prefix_ops(self.cfg, &mut self.stats, k, t, &ops);
                    }
                }
            }
        }
    }

    /// A KV ingest finished: the migrated sequence joins the queue
    /// (its pages move at admission, through the standard refill
    /// gate) — unless the instance started draining meanwhile, in
    /// which case the entry bounces to another serving instance.
    fn finish_ingest(&mut self, k: usize, _t: f64) {
        self.insts[k].work_end = None;
        if let Some(iv) = self.insts[k].cur_iv.take() {
            self.stats.trace.close(iv);
        }
        let job = self.insts[k]
            .ingest
            .pop_front()
            .expect("ingest completion without a job");
        if self.insts[k].state == InstanceState::Draining {
            self.redispatch(job.entry, true);
        } else {
            self.insts[k].queue.push_back(job.entry);
        }
    }

    /// Model load finished: the instance starts admitting, and limbo
    /// entries that were waiting for capacity get routed.
    fn finish_warmup(&mut self, k: usize, _t: f64) {
        self.insts[k].work_end = None;
        if let Some(iv) = self.insts[k].cur_iv.take() {
            self.stats.trace.close(iv);
        }
        self.insts[k].state = InstanceState::Serving;
        self.resolve_limbo();
        self.stats.kick.insert(k);
    }

    /// Schedule the instance's next unit of work at `t`: a pending KV
    /// ingest if any (the transfer occupies the engine), else a batcher
    /// iteration through the shared `plan_refill` admission core. Only
    /// serving instances start work. With a prefix store configured,
    /// each fresh admission first consults the cache: reused tokens
    /// drop out of the iteration's prefill term and the fetch time
    /// stalls the iteration instead.
    fn start_work(&mut self, k: usize, t: f64) {
        let cfg = self.cfg;
        // device map snapshot: remote prefix fetches price the fabric
        // between a run's home device and this instance
        let devices: Vec<DeviceId> = if self.prefix.is_some() {
            self.insts.iter().map(|i| i.device).collect()
        } else {
            Vec::new()
        };
        let prefix = &mut self.prefix;
        let stats = &mut self.stats;
        let inst = &mut self.insts[k];
        debug_assert!(inst.work_end.is_none(), "work already in flight");
        if inst.state != InstanceState::Serving {
            return;
        }
        if let Some(job) = inst.ingest.front() {
            let finish = t + job.xfer;
            inst.cur_iv = Some(stats.trace.open(ResourceId(k), t, finish, tags::KV_XFER));
            inst.work_end = Some((finish, Work::Ingest));
            return;
        }
        grow_active(inst, cfg, stats);
        let mut total_prefill = 0usize;
        let mut cached_prefill = 0usize;
        let mut fetch_time = 0.0f64;
        loop {
            let occupied: Vec<bool> = inst.active.iter().map(Option::is_some).collect();
            let empty = occupied.iter().filter(|o| !**o).count();
            // (id, prompt_len, produced) of the admissible queue prefix
            let heads: Vec<(u64, usize, usize)> = inst
                .queue
                .iter()
                .take(empty)
                .map(|q| (q.req.id, q.prompt_len, q.produced))
                .collect();
            let lens: Vec<usize> = heads.iter().map(|h| h.1).collect();
            let cold = cold_order(inst);
            let mem = &mut inst.mem;
            let plan = plan_refill(&occupied, cfg.max_seq, &lens, |qi, prompt_len| {
                // migrated sequences carry their produced tokens: the gate
                // reserves pages for the full context at this instance
                let pages = mem.pages_for(prompt_len + heads[qi].2);
                pages <= mem.pool.hbm_capacity()
                    && mem.ensure_hbm_free(pages, &cold)
                    && mem.pool.try_alloc_hbm(heads[qi].0, pages)
            });
            for adm in &plan {
                let q = inst.queue.pop_front().expect("refill plan exceeds queue");
                if q.produced == 0 {
                    total_prefill += adm.prompt_len;
                    if let Some(store) = prefix.as_mut() {
                        let (cached, ft) =
                            prefix_admit(cfg, store, stats, &devices, k, t, &q.req, adm.prompt_len);
                        cached_prefill += cached;
                        fetch_time += ft;
                    }
                }
                if let Some(src) = q.kv_src {
                    // pages now live here; the parked copy at the source
                    // is released in the cluster-level drain
                    stats.handoffs.push((q.req.id, src));
                }
                inst.active[adm.slot] = Some(ActiveSeq {
                    req: q.req,
                    prompt_len: adm.prompt_len,
                    produced: q.produced,
                    admitted_at: t,
                    first_token: q.first_token,
                    preemptions: q.preemptions,
                });
            }
            if !plan.is_empty() || inst.active_count() > 0 {
                break;
            }
            // Empty instance, nothing admitted. Reject the head only if it
            // can NEVER fit; a head blocked on pages parked elsewhere (or
            // an in-flight ingest) waits — the release re-kicks us.
            match inst.queue.front() {
                Some(head) => {
                    let pages = inst
                        .mem
                        .pages_for(head.prompt_len.min(cfg.max_seq - 1) + head.produced);
                    if pages > inst.mem.pool.hbm_capacity() {
                        let q = inst.queue.pop_front().expect("head exists");
                        if let Some(src) = q.kv_src {
                            stats.handoffs.push((q.req.id, src));
                        }
                        stats.rejected += 1;
                    } else {
                        break;
                    }
                }
                None => break,
            }
        }

        // Cost the iteration from the tiered KV footprint (same split as
        // the single-instance batcher).
        let tpp = inst.mem.tokens_per_page();
        let mut hbm_tokens = 0usize;
        let mut pool_tokens = 0usize;
        for seq in inst.active.iter().flatten() {
            let ctx = seq.ctx();
            let in_pool = (inst.mem.pool.seq_pages(seq.req.id).pool * tpp).min(ctx);
            pool_tokens += in_pool;
            hbm_tokens += ctx - in_pool;
        }
        inst.cur_ctx_tokens = hbm_tokens + pool_tokens;
        if inst.active_count() == 0 {
            return;
        }
        // cache-hit tokens skip recompute; their fetch stalls the
        // iteration instead (fetch_time == 0.0 without a prefix store,
        // keeping the cache-disabled schedule bit-identical)
        let compute_prefill = total_prefill - cached_prefill;
        stats.prefill_tokens += compute_prefill as u64;
        let finish = t
            + fetch_time
            + cfg
                .cost
                .iteration_latency(hbm_tokens, pool_tokens, compute_prefill);
        let tag = if compute_prefill > 0 {
            tags::PREFILL
        } else {
            tags::DECODE
        };
        inst.cur_iv = Some(stats.trace.open(ResourceId(k), t, finish, tag));
        inst.work_end = Some((finish, Work::Iteration));
    }

    /// Time/class/index of the next internal event, or `None` when the
    /// run is complete. Class breaks ties at equal times — arrival <
    /// work-end < crash < autoscale tick < retry-due, lowest instance
    /// index first among simultaneous work-ends. A pending tick alone
    /// never keeps the sim alive (ticks are cancelled once nothing can
    /// generate further work) — but a parked retry does.
    pub(crate) fn next_event(&self) -> Option<(f64, u8, usize)> {
        let mut best: Option<(f64, u8, usize)> = None;
        if let Some(r) = self.requests.get(self.next_arrival) {
            best = Some((r.arrival, 0, 0));
        }
        for (k, inst) in self.insts.iter().enumerate() {
            if let Some((wt, _)) = inst.work_end {
                let cand = (wt, 1u8, k);
                if best.map_or(true, |b| event_lt(cand, b)) {
                    best = Some(cand);
                }
            }
        }
        if let Some(f) = self.failures.get(self.next_failure) {
            let cand = (f.time, 2u8, self.next_failure);
            if best.map_or(true, |b| event_lt(cand, b)) {
                best = Some(cand);
            }
        }
        for (i, r) in self.retries.iter().enumerate() {
            let cand = (r.due, 4u8, i);
            if best.map_or(true, |b| event_lt(cand, b)) {
                best = Some(cand);
            }
        }
        let mut ev = best?;
        if let Some(tk) = self.next_tick {
            let cand = (tk, 3u8, 0usize);
            if event_lt(cand, ev) {
                ev = cand;
            }
        }
        Some(ev)
    }

    /// Execute one event returned by [`next_event`], then drain its
    /// cross-instance effects to quiescence. Device acquisitions and
    /// clean releases go through `lessor` (the private `device_pool`
    /// is tried/used first — standalone runs pass [`NullLessor`] and
    /// behave exactly as before).
    pub(crate) fn process(&mut self, ev: (f64, u8, usize), lessor: &mut dyn DeviceLessor) {
        let cfg = self.cfg;
        let (t, cls, idx) = ev;
        self.now = t;
        match cls {
            0 => {
                let req = self.requests[self.next_arrival];
                self.next_arrival += 1;
                if cfg.autoscale.is_some() {
                    self.recent_arrivals.push_back(t);
                }
                // fresh arrivals take the same admission path as
                // crash/drain re-queues: route to a serving
                // instance (the kick-drain below wakes it), wait
                // in limbo while capacity warms, or reject if no
                // capacity can ever come
                self.route_requeue(
                    Queued {
                        req,
                        prompt_len: req.prompt_tokens,
                        produced: 0,
                        first_token: None,
                        preemptions: 0,
                        kv_src: None,
                    },
                    None,
                );
            }
            1 => {
                let k = idx;
                let kind = self.insts[k].work_end.expect("work in flight").1;
                match kind {
                    Work::Iteration => self.finish_iteration(k, t),
                    Work::Ingest => self.finish_ingest(k, t),
                    Work::Warmup => self.finish_warmup(k, t),
                }
                if self.insts[k].work_end.is_none() {
                    self.start_work(k, t);
                }
            }
            2 => {
                self.next_failure += 1;
                let sel = self.failures[idx].instance;
                self.crash_instance(sel, t, lessor);
            }
            4 => {
                let r = self.retries.remove(idx);
                if r.entry.kv_src.is_some() {
                    self.dispatch_migration(r.entry, r.drain, r.attempts, Some(r.exclude));
                } else {
                    // the source crashed while we waited: nothing is
                    // parked anymore, go back through the front-end
                    // router (which still avoids the slow instance)
                    self.route_requeue(r.entry, Some(r.exclude));
                }
            }
            _ => {
                self.autoscale_tick(t, lessor);
                let aus = cfg.autoscale.as_ref().expect("tick requires autoscale");
                self.next_tick = Some(t + aus.eval_interval);
            }
        }
        // Drain cross-instance effects until quiescent: page handoffs
        // wake the source instance, migrations/requeues wake targets.
        while !self.stats.handoffs.is_empty() || !self.stats.kick.is_empty() {
            let handoffs = std::mem::take(&mut self.stats.handoffs);
            for (seq, src) in handoffs {
                debug_assert!(
                    self.insts[src].state != InstanceState::Crashed,
                    "page handoff against a crashed source"
                );
                self.insts[src].mem.pool.release(seq);
                self.stats.kick.insert(src);
            }
            let kicks: Vec<usize> = std::mem::take(&mut self.stats.kick).into_iter().collect();
            for k in kicks {
                if self.insts[k].work_end.is_none() {
                    self.start_work(k, t);
                }
            }
        }
        // a drained instance releases its device once its parked
        // pages are gone and nothing is in flight
        for k2 in 0..self.insts.len() {
            let inst = &self.insts[k2];
            if inst.state == InstanceState::Draining
                && inst.work_end.is_none()
                && inst.queue.is_empty()
                && inst.ingest.is_empty()
                && inst.active_count() == 0
                && inst.mem.pool.sequences() == 0
            {
                self.insts[k2].state = InstanceState::Released;
                self.insts[k2].died = Some(t);
                // the released device's memory goes back to the pool:
                // prefix runs homed there (HBM or pooled) are lost
                if let Some(store) = self.prefix.as_mut() {
                    store.invalidate_instance(k2);
                }
                self.stats.trace.push(ResourceId(k2), t, t, tags::DRAIN);
                let dev = self.insts[k2].device;
                if !lessor.give_back(dev) {
                    self.pool_devices.push_back(dev);
                }
            }
        }
        let total_ctx: usize = self.insts.iter().map(|i| i.cur_ctx_tokens).sum();
        self.peak_context = self.peak_context.max(total_ctx);
        let alive = self
            .insts
            .iter()
            .filter(|i| {
                matches!(
                    i.state,
                    InstanceState::Serving | InstanceState::WarmingUp | InstanceState::Draining
                )
            })
            .count();
        self.peak_alive = self.peak_alive.max(alive);
        // ticks stop once nothing can generate further work
        if self.next_tick.is_some()
            && self.next_arrival >= self.requests.len()
            && self.next_failure >= self.failures.len()
            && self.retries.is_empty()
            && self.insts.iter().all(|i| i.work_end.is_none())
        {
            self.next_tick = None;
        }
    }
}

impl<'a> ClusterSim<'a> {
    /// Validate the configuration and build the initial state. Panics
    /// on malformed configs (same checks [`simulate_cluster`] always
    /// applied).
    pub(crate) fn new(cfg: &'a ClusterConfig, requests: &'a [Request]) -> Self {
        assert!(!cfg.instances.is_empty(), "cluster needs at least one instance");
        assert!(cfg.max_seq >= 2, "need room for a prompt and one decode position");
        debug_assert!(
            requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "requests must be sorted by arrival time"
        );
        let has_prefill = cfg
            .instances
            .iter()
            .any(|i| i.role == InstanceRole::Prefill);
        let has_decode = cfg.instances.iter().any(|i| i.role == InstanceRole::Decode);
        let has_colocated = cfg
            .instances
            .iter()
            .any(|i| i.role == InstanceRole::Colocated);
        assert!(
            !(has_colocated && (has_prefill || has_decode)),
            "mixing colocated with disaggregated roles is not supported"
        );
        assert!(
            has_prefill == has_decode,
            "disaggregation needs both a prefill pool and a decode pool"
        );
        if let Some(aus) = &cfg.autoscale {
            assert!(aus.slots >= 1, "autoscaled instances need at least one slot");
            assert!(aus.eval_interval > 0.0, "evaluation cadence must be positive");
            assert!(aus.lookback > 0.0, "lookback window must be positive");
            assert!(
                aus.min_instances >= 1 && aus.max_instances >= aus.min_instances,
                "need 1 <= min_instances <= max_instances"
            );
        }

        let insts: Vec<Instance> = cfg
            .instances
            .iter()
            .map(|spec| Instance::new(spec, cfg))
            .collect();
        let entry_role = if has_prefill {
            InstanceRole::Prefill
        } else {
            InstanceRole::Colocated
        };
        let scaled_role = if has_decode {
            InstanceRole::Decode
        } else {
            InstanceRole::Colocated
        };
        let mut failures = cfg.failures.clone();
        failures.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.instance.cmp(&b.instance)));
        let n0 = insts.len();
        Self {
            cfg,
            requests,
            insts,
            router: Router::new(cfg.route),
            stats: Stats {
                per_instance_completed: vec![0; n0],
                trace: TraceCollector::new(cfg.trace_mode),
                ..Default::default()
            },
            limbo: VecDeque::new(),
            pool_devices: cfg
                .autoscale
                .as_ref()
                .map(|a| a.device_pool.iter().copied().collect())
                .unwrap_or_default(),
            entry_role,
            scaled_role,
            last_action: f64::NEG_INFINITY,
            recent_arrivals: VecDeque::new(),
            outcome_ptr: 0,
            peak_context: 0,
            peak_alive: n0,
            failures,
            next_arrival: 0,
            next_failure: 0,
            next_tick: cfg.autoscale.as_ref().map(|a| a.eval_interval),
            now: 0.0,
            retries: Vec::new(),
            prefix: cfg
                .prefix
                .as_ref()
                .map(|p| PrefixStore::new(p.clone(), cfg.cost.kv.tokens_per_page)),
        }
    }

    /// Finalize a completed run into the report, asserting the page
    /// conservation invariants.
    pub(crate) fn into_report(self) -> ClusterReport {
        // makespan: latest finish of real work (zero-length markers from
        // crash/drain events don't extend the served timeline) — read
        // from the running accumulators, no interval scan
        let makespan = self.stats.trace.accum().real_makespan();

        // Conservation: every live pool fully drained — no page leaked
        // across completions, preemptions, migrations, drains, or crashes
        // (a crashed pool was wiped at the instant of death).
        for (i, inst) in self.insts.iter().enumerate() {
            if inst.state == InstanceState::Crashed {
                continue;
            }
            assert_eq!(
                inst.mem.pool.sequences(),
                0,
                "instance {i} leaked pages for {} sequences",
                inst.mem.pool.sequences()
            );
            inst.mem
                .pool
                .check_conservation()
                .unwrap_or_else(|e| panic!("instance {i}: {e}"));
        }
        assert!(self.limbo.is_empty(), "limbo entries leaked");
        assert!(self.retries.is_empty(), "retry entries leaked");
        if let Some(store) = &self.prefix {
            store
                .check_conservation()
                .unwrap_or_else(|e| panic!("prefix store: {e}"));
        }

        let demotions = self.insts.iter().map(|i| i.mem.pool.demotions).sum();
        let instance_seconds: f64 = self
            .insts
            .iter()
            .map(|i| (i.died.unwrap_or(makespan) - i.born).max(0.0))
            .sum();
        let n = self.insts.len();
        let instance_devices: Vec<DeviceId> = self.insts.iter().map(|i| i.device).collect();
        let held_devices_at_end: Vec<DeviceId> = self
            .insts
            .iter()
            .filter(|i| {
                matches!(
                    i.state,
                    InstanceState::Serving | InstanceState::WarmingUp | InstanceState::Draining
                )
            })
            .map(|i| i.device)
            .collect();
        let crashed_devices: Vec<DeviceId> = self
            .insts
            .iter()
            .filter(|i| i.state == InstanceState::Crashed)
            .map(|i| i.device)
            .collect();
        let peak_instances = self.peak_alive;
        let peak_context = self.peak_context;
        let Stats {
            outcomes,
            rejected,
            preemptions,
            decoded_tokens,
            prefill_tokens,
            trace,
            kv_migrations,
            kv_bytes,
            kv_xfer_time,
            per_instance_completed,
            crashes,
            crash_requeues,
            scale_ups,
            scale_downs,
            drain_migrations,
            warmup_time,
            retries_scheduled,
            hedged,
            prefix_hits,
            prefix_misses,
            prefix_hit_tokens,
            prefix_prompt_tokens,
            prefix_recomputed_tokens,
            prefix_fetch_time,
            prefix_demote_time,
            prefix_promotions,
            prefix_demotions,
            prefix_evictions,
            ..
        } = self.stats;
        ClusterReport {
            serving: ServingReport {
                outcomes,
                rejected,
                preemptions,
                demotions,
                decoded_tokens,
                prefill_tokens,
                peak_context_tokens: peak_context,
                makespan,
                trace: trace.finish(makespan, n),
            },
            kv_migrations,
            kv_bytes_migrated: kv_bytes,
            kv_xfer_time,
            per_instance_completed,
            crashes,
            crash_requeues,
            scale_ups,
            scale_downs,
            drain_migrations,
            warmup_time,
            retries_scheduled,
            hedged,
            instance_seconds,
            peak_instances,
            instance_devices,
            held_devices_at_end,
            crashed_devices,
            prefix_hits,
            prefix_misses,
            prefix_hit_tokens,
            prefix_prompt_tokens,
            prefix_recomputed_tokens,
            prefix_fetch_time,
            prefix_demote_time,
            prefix_promotions,
            prefix_demotions,
            prefix_evictions,
        }
    }
}

/// Run the cluster simulation to completion: every request is either
/// completed or rejected exactly once when this returns — including
/// under injected crashes and elastic scale-downs — and every
/// non-crashed instance's page pool has drained. Deterministic:
/// identical inputs produce a bit-identical report.
pub fn simulate_cluster(cfg: &ClusterConfig, requests: &[Request]) -> ClusterReport {
    let mut sim = ClusterSim::new(cfg, requests);
    let mut lessor = NullLessor;
    while let Some(ev) = sim.next_event() {
        sim.process(ev, &mut lessor);
    }
    sim.into_report()
}

// ---- scenarios and sweeps ---------------------------------------------

/// Cluster deployment + workload + arrival window.
#[derive(Debug, Clone)]
pub struct ClusterScenario {
    pub cluster: ClusterConfig,
    pub workload: WorkloadConfig,
    /// Arrival window, virtual seconds (the run drains afterwards).
    pub horizon: f64,
}

/// Generate the workload and run the cluster simulator.
pub fn run_cluster_scenario(sc: &ClusterScenario) -> ClusterReport {
    simulate_cluster(&sc.cluster, &sc.workload.generate(sc.horizon))
}

/// Sweep offered load over the cluster, fanned across `sim::sweep`
/// workers. Results are in input order and bit-identical to a
/// sequential loop. Thin wrapper over the `rate`
/// [`SweepSpec`](crate::sim::SweepSpec) axis.
pub fn cluster_rate_sweep(
    base: &ClusterScenario,
    rates: &[f64],
    slo: &Slo,
) -> Vec<OperatingPoint> {
    crate::sim::SweepSpec::over("rate", rates.to_vec()).values(|&rate| {
        let mut sc = base.clone();
        sc.workload.arrival = sc.workload.arrival.with_mean_rate(rate);
        run_cluster_scenario(&sc).operating_point(rate, slo)
    })
}

/// Place `n` instances spread across the topology's racks (one per
/// rack, wrapping onto successive boards, then onto successive dies) —
/// the placement that exposes the cross-rack fabric tier to
/// migrations. `n` is clamped to the device count, so the returned
/// devices are always distinct; use [`try_spread_placement`] to treat
/// an oversized `n` as an error instead.
pub fn spread_placement(topo: &Topology, n: usize) -> Vec<DeviceId> {
    try_spread_placement(topo, n.min(topo.geometry.device_count()))
        .expect("clamped placement always fits")
}

/// Fallible spread placement: errors when `n` exceeds the device
/// count. (The old behavior silently wrapped onto already-used
/// devices, handing several instances the same chip.)
pub fn try_spread_placement(topo: &Topology, n: usize) -> Result<Vec<DeviceId>, String> {
    let g = topo.geometry;
    let total = g.device_count();
    if n > total {
        return Err(format!(
            "cannot place {n} instances on {total} devices ({} racks x {} boards x {} dies)",
            g.racks, g.boards_per_rack, g.dies_per_board
        ));
    }
    Ok((0..n)
        .map(|i| {
            let rack = i % g.racks;
            let board = (i / g.racks) % g.boards_per_rack;
            let die = (i / (g.racks * g.boards_per_rack)) % g.dies_per_board;
            DeviceId(rack * g.boards_per_rack * g.dies_per_board + board * g.dies_per_board + die)
        })
        .collect())
}

// ---- the checked-in crossover presets ---------------------------------

/// Which fabric the crossover scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterFabric {
    /// Matrix384 UB supernode (pooled memory, ~15x cross-machine bw).
    Supernode,
    /// Legacy PCIe/RoCE cluster of comparable scale.
    Legacy,
}

impl ClusterFabric {
    pub fn topology(self) -> Topology {
        match self {
            ClusterFabric::Supernode => Topology::matrix384(),
            ClusterFabric::Legacy => Topology::legacy_cluster(32),
        }
    }
}

/// Serving architecture under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    Colocated,
    Disaggregated,
}

/// Llama-8B-class device scaled so the crossover runs at CI size: the
/// bandwidth ratios of `KvCacheConfig::llama8b_910c`, with HBM for 40K
/// KV tokens beyond the weights — room for a decode pool batching long
/// prompts, small enough that runs stay fast.
pub fn cluster_device() -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 131_072,
        tokens_per_page: 64,
        weight_bytes: 8 * (1u64 << 30),
        hbm_usable: 8 * (1u64 << 30) + 40_960 * 131_072,
        hbm_bw: 1.6e12,
        pool_bw: 392e9,
        attn_tokens_per_s: 40e6,
    }
}

/// The long-prompt mix where disaggregation matters: ~2K-token
/// prompts (a 20 ms inline prefill stall per admission for colocated
/// batchers, ~260 MB of KV per migration for disaggregated ones),
/// short chat-style outputs.
pub fn long_prompt_workload(rate: f64) -> WorkloadConfig {
    WorkloadConfig {
        arrival: ArrivalProcess::Poisson { rate },
        prompt: LengthDist::Uniform { lo: 1600, hi: 2400 },
        output: LengthDist::Uniform { lo: 16, hi: 32 },
        seed: 42,
    }
}

/// The crossover scenarios' SLO: 500 ms to first token, 13 ms/token
/// after — the TPOT bound sits between a clean decode iteration
/// (~9 ms) and one contaminated by inline prefill or staged KV copies.
pub fn cluster_slo() -> Slo {
    Slo {
        ttft_p99: 0.5,
        tpot_p99: 0.013,
    }
}

/// The fixed rate grid of the crossover comparison (cluster-wide QPS).
pub const CLUSTER_RATES: [f64; 8] = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0];

/// Four instances on the fabric, spread across racks. Colocated: four
/// full batchers. Disaggregated: two prefill instances (small slot
/// count — prompts churn fast) feeding two decode instances (large
/// batches — decode is memory-bound, batching is cheap).
pub fn crossover_cluster(fabric: ClusterFabric, mode: ClusterMode) -> ClusterConfig {
    let topology = fabric.topology();
    let places = spread_placement(&topology, 4);
    let instances = match mode {
        ClusterMode::Colocated => places
            .iter()
            .map(|&device| InstanceSpec {
                device,
                role: InstanceRole::Colocated,
                slots: 12,
            })
            .collect(),
        ClusterMode::Disaggregated => vec![
            InstanceSpec {
                device: places[0],
                role: InstanceRole::Prefill,
                slots: 4,
            },
            InstanceSpec {
                device: places[1],
                role: InstanceRole::Prefill,
                slots: 4,
            },
            InstanceSpec {
                device: places[2],
                role: InstanceRole::Decode,
                slots: 16,
            },
            InstanceSpec {
                device: places[3],
                role: InstanceRole::Decode,
                slots: 16,
            },
        ],
    };
    ClusterConfig::builder(topology, instances, CostModel::new(cluster_device(), 0.0)).build()
}

/// The checked-in crossover scenario for one (fabric, mode) cell.
pub fn crossover_scenario(fabric: ClusterFabric, mode: ClusterMode) -> ClusterScenario {
    ClusterScenario {
        cluster: crossover_cluster(fabric, mode),
        workload: long_prompt_workload(CLUSTER_RATES[0]),
        horizon: 8.0,
    }
}

/// Max-QPS-under-SLO operating points of the four (fabric × mode)
/// cells — the paper-shaped result: disaggregation wins on the
/// supernode fabric and loses on the legacy fabric, because KV
/// migration cost is the deciding term.
#[derive(Debug, Clone, Copy)]
pub struct CrossoverSummary {
    pub colocated_supernode: OperatingPoint,
    pub disagg_supernode: OperatingPoint,
    pub colocated_legacy: OperatingPoint,
    pub disagg_legacy: OperatingPoint,
}

impl CrossoverSummary {
    /// Disaggregation speedup on the supernode fabric.
    pub fn supernode_disagg_gain(&self) -> f64 {
        self.disagg_supernode.rate / self.colocated_supernode.rate
    }

    /// Colocation advantage on the legacy fabric.
    pub fn legacy_colocated_gain(&self) -> f64 {
        self.colocated_legacy.rate / self.disagg_legacy.rate
    }
}

/// Run the full crossover comparison on the fixed grid (each cell's
/// rate sweep fans out through `sim::sweep`).
pub fn crossover_comparison() -> CrossoverSummary {
    let cell = |fabric, mode| {
        let points = cluster_rate_sweep(
            &crossover_scenario(fabric, mode),
            &CLUSTER_RATES,
            &cluster_slo(),
        );
        max_qps_under_slo(&points)
            .unwrap_or_else(|| panic!("{fabric:?}/{mode:?} must attain at the lowest rate"))
    };
    CrossoverSummary {
        colocated_supernode: cell(ClusterFabric::Supernode, ClusterMode::Colocated),
        disagg_supernode: cell(ClusterFabric::Supernode, ClusterMode::Disaggregated),
        colocated_legacy: cell(ClusterFabric::Legacy, ClusterMode::Colocated),
        disagg_legacy: cell(ClusterFabric::Legacy, ClusterMode::Disaggregated),
    }
}

// ---- the checked-in fleet disaggregated-prefill preset (ISSUE 9) ------

/// Cross-supernode disaggregated prefill on [`Fleet::dual_supernode`]:
/// eight instances split over two 32-device supernodes joined by a
/// DCN-class inter-node link.
///
/// `aware = true` gives each supernode a complete prefill→decode
/// pipeline (2 Prefill + 2 Decode per pool), so the fleet-aware
/// migration policy keeps every ~260 MB KV handoff on the local UB
/// fabric. `aware = false` is the naive role-per-supernode split —
/// all prefill on sn0, all decode on sn1 — which forces every handoff
/// across the inter-supernode link (and disables the same-pool
/// destination preference). Same device budget, same workload; only
/// the placement and routing policy differ.
pub fn fleet_prefill_scenario(aware: bool) -> ClusterScenario {
    let fleet = Fleet::dual_supernode();
    let topology = fleet.flatten();
    let p0 = spread_placement(&fleet.pools[0].topo, 4);
    let p1: Vec<DeviceId> = spread_placement(&fleet.pools[1].topo, 4)
        .into_iter()
        .map(|d| fleet.global(1, d))
        .collect();
    let spec = |device, role, slots| InstanceSpec { device, role, slots };
    let instances = if aware {
        vec![
            spec(p0[0], InstanceRole::Prefill, 4),
            spec(p0[1], InstanceRole::Prefill, 4),
            spec(p0[2], InstanceRole::Decode, 16),
            spec(p0[3], InstanceRole::Decode, 16),
            spec(p1[0], InstanceRole::Prefill, 4),
            spec(p1[1], InstanceRole::Prefill, 4),
            spec(p1[2], InstanceRole::Decode, 16),
            spec(p1[3], InstanceRole::Decode, 16),
        ]
    } else {
        vec![
            spec(p0[0], InstanceRole::Prefill, 4),
            spec(p0[1], InstanceRole::Prefill, 4),
            spec(p0[2], InstanceRole::Prefill, 4),
            spec(p0[3], InstanceRole::Prefill, 4),
            spec(p1[0], InstanceRole::Decode, 16),
            spec(p1[1], InstanceRole::Decode, 16),
            spec(p1[2], InstanceRole::Decode, 16),
            spec(p1[3], InstanceRole::Decode, 16),
        ]
    };
    let cluster =
        ClusterConfig::builder(topology, instances, CostModel::new(cluster_device(), 0.0))
            .fleet(fleet)
            .fleet_aware_placement(aware)
            .build();
    ClusterScenario {
        cluster,
        workload: long_prompt_workload(2.0 * CLUSTER_RATES[0]),
        horizon: 8.0,
    }
}

// ---- the checked-in elastic-autoscaling presets (ISSUE 4) -------------

/// Mean offered rate of the diurnal autoscale scenario, requests/s.
pub const AUTOSCALE_MEAN_RATE: f64 = 24.0;
/// Day length (and arrival horizon) of the scenario, virtual seconds.
pub const AUTOSCALE_PERIOD: f64 = 48.0;
/// Static peak provisioning: instances sized to hold the SLO at the
/// diurnal peak with ~20% headroom.
pub const AUTOSCALE_STATIC_INSTANCES: usize = 9;
/// Elastic bounds and starting size.
pub const AUTOSCALE_MAX_INSTANCES: usize = 10;
pub const AUTOSCALE_INITIAL_INSTANCES: usize = 4;
/// Batching slots per instance (small slots = fine-grained capacity).
pub const AUTOSCALE_SLOTS: usize = 4;

/// 8B-class device at bf16 for the elastic scenario: twice the
/// crossover device's weights (16 GiB), which is what makes the
/// model-load warm-up decisively fabric-dependent — ~88 ms over the
/// supernode's pooled-memory fabric vs ~1.4 s over legacy RoCE.
pub fn autoscale_device() -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 131_072,
        tokens_per_page: 64,
        weight_bytes: 16 * (1u64 << 30),
        hbm_usable: 16 * (1u64 << 30) + 40_960 * 131_072,
        hbm_bw: 1.6e12,
        pool_bw: 392e9,
        attn_tokens_per_s: 40e6,
    }
}

/// The diurnal multi-tenant workload of the autoscale scenario: a
/// ≥4x peak-to-trough swing (two staggered tenants), mid-length
/// prompts, short chat outputs, fixed seed.
pub fn autoscale_workload(mean_rate: f64) -> WorkloadConfig {
    WorkloadConfig {
        arrival: diurnal_two_tenant(mean_rate, AUTOSCALE_PERIOD),
        prompt: LengthDist::Uniform { lo: 600, hi: 1000 },
        output: LengthDist::Uniform { lo: 48, hi: 80 },
        seed: 42,
    }
}

/// The autoscale scenario's SLO: 500 ms to first token, 20 ms/token.
pub fn autoscale_slo() -> Slo {
    Slo {
        ttft_p99: 0.5,
        tpot_p99: 0.02,
    }
}

/// The scenario's scaling policy: queue-depth with a hysteresis band —
/// scale up above 0.9 backlog per committed slot, down when the
/// backlog would still fit under 0.75 of the remaining capacity.
pub fn autoscale_policy() -> AutoscalePolicy {
    AutoscalePolicy::QueueDepth {
        scale_up_backlog: 0.9,
        scale_down_backlog: 0.75,
    }
}

/// The full autoscaler preset of the diurnal scenarios (policy +
/// cadence + cooldowns + bounds), shared by [`autoscale_cluster`] and
/// the co-scheduled scenario (`hypermpmd::coschedule`) so the two can
/// never drift apart. `device_pool` is the only per-scenario knob:
/// spare devices for a standalone cluster, empty for a broker-backed
/// one.
pub fn autoscale_preset(device_pool: Vec<DeviceId>) -> AutoscaleConfig {
    AutoscaleConfig {
        policy: autoscale_policy(),
        eval_interval: 0.25,
        min_instances: 1,
        max_instances: AUTOSCALE_MAX_INSTANCES,
        slots: AUTOSCALE_SLOTS,
        up_cooldown: 0.2,
        down_cooldown: 0.5,
        lookback: 2.0,
        device_pool,
    }
}

/// Cluster config of the autoscale comparison. `elastic = false` is
/// the static-peak-provisioning baseline ([`AUTOSCALE_STATIC_INSTANCES`]
/// always-on instances); `elastic = true` starts at
/// [`AUTOSCALE_INITIAL_INSTANCES`] and lets the queue-depth policy
/// track the diurnal swing. `spare_devices` extends the device pool
/// beyond [`AUTOSCALE_MAX_INSTANCES`] so crash replacements have a
/// chip to land on after a device dies.
pub fn autoscale_cluster(
    fabric: ClusterFabric,
    elastic: bool,
    spare_devices: usize,
) -> ClusterConfig {
    let topology = fabric.topology();
    let n0 = if elastic {
        AUTOSCALE_INITIAL_INSTANCES
    } else {
        AUTOSCALE_STATIC_INSTANCES
    };
    let places = spread_placement(&topology, AUTOSCALE_MAX_INSTANCES + spare_devices);
    let instances = places[..n0]
        .iter()
        .map(|&device| InstanceSpec {
            device,
            role: InstanceRole::Colocated,
            slots: AUTOSCALE_SLOTS,
        })
        .collect();
    let mut b = ClusterConfig::builder(
        topology,
        instances,
        CostModel::new(autoscale_device(), 0.0),
    );
    if let Some(aus) = elastic.then(|| autoscale_preset(places[n0..].to_vec())) {
        b = b.autoscale(aus);
    }
    b.build()
}

/// The checked-in diurnal scenario for one (fabric, elastic) cell.
pub fn autoscale_scenario(fabric: ClusterFabric, elastic: bool) -> ClusterScenario {
    ClusterScenario {
        cluster: autoscale_cluster(fabric, elastic, 0),
        workload: autoscale_workload(AUTOSCALE_MEAN_RATE),
        horizon: AUTOSCALE_PERIOD,
    }
}

/// The crash-recovery scenario: the elastic cluster with one serving
/// instance killed at mid-day (peak traffic), and a spare device for
/// the replacement.
pub fn autoscale_crash_scenario(fabric: ClusterFabric) -> ClusterScenario {
    let mut cluster = autoscale_cluster(fabric, true, 1);
    cluster.failures = vec![InstanceCrash {
        time: AUTOSCALE_PERIOD * 0.5,
        instance: 0,
    }];
    ClusterScenario {
        cluster,
        workload: autoscale_workload(AUTOSCALE_MEAN_RATE),
        horizon: AUTOSCALE_PERIOD,
    }
}

/// Static-vs-elastic comparison on one fabric: the headline numbers
/// the scenario test, bench gate, and example all read.
#[derive(Debug, Clone)]
pub struct AutoscaleSummary {
    pub static_report: ClusterReport,
    pub elastic_report: ClusterReport,
}

impl AutoscaleSummary {
    /// Fraction of instance-seconds elastic scaling saves vs static
    /// peak provisioning.
    pub fn instance_seconds_saved(&self) -> f64 {
        1.0 - self.elastic_report.instance_seconds / self.static_report.instance_seconds
    }
}

/// Run the static and elastic diurnal scenarios on one fabric.
pub fn autoscale_comparison(fabric: ClusterFabric) -> AutoscaleSummary {
    AutoscaleSummary {
        static_report: run_cluster_scenario(&autoscale_scenario(fabric, false)),
        elastic_report: run_cluster_scenario(&autoscale_scenario(fabric, true)),
    }
}

// ---- the checked-in agentic prefix-cache presets (ISSUE 7) ------------

/// The fixed rate grid of the agentic comparison (cluster-wide
/// request QPS).
pub const AGENTIC_RATES: [f64; 8] = [10.0, 15.0, 20.0, 25.0, 30.0, 40.0, 50.0, 60.0];

/// The rate where the hit-rate / recompute-ratio numbers are read —
/// low enough that both routers attain the SLO on both fabrics, so
/// the ratio compares like with like.
pub const AGENTIC_COMPARE_RATE: f64 = 10.0;

/// Prefix-cache capacity of the agentic scenario on one fabric. The
/// HBM carve-out is deliberately small (64 pages = 4K tokens, ~0.6%
/// of an instance's HBM; the offload policy's 30% reserve shrinks it
/// to 44): barely two system prompts fit, so session histories
/// overflow almost immediately. Where they overflow is the fabric
/// story — the supernode demotes into pooled DRAM at 392 GB/s (a
/// fetch beats recompute ~30x), the legacy cluster has no pooled
/// tier (`pool_pages: 0`) and spills straight to host at 8 GB/s,
/// where a fetch *loses* to recompute and the cache stops paying.
pub fn agentic_prefix(fabric: ClusterFabric) -> PrefixCacheConfig {
    PrefixCacheConfig {
        hbm_pages_per_instance: 64,
        pool_pages: match fabric {
            ClusterFabric::Supernode => 8192,
            ClusterFabric::Legacy => 0,
        },
        host_pages: 8192,
        host_bw: 8e9,
        policy: OffloadPolicy::new(cluster_device().hbm_usable),
    }
}

/// Four colocated instances spread across racks, as in the crossover
/// scenario. `cache_aware` flips both halves of the tentpole at
/// once: the fleet-wide prefix store and the router that exploits
/// it. The baseline is cache-blind [`RoutePolicy::SessionAffinity`]
/// with no store at all — its recomputed-token ratio is 1.0 by
/// construction.
pub fn agentic_cluster(fabric: ClusterFabric, cache_aware: bool) -> ClusterConfig {
    let topology = fabric.topology();
    let instances = spread_placement(&topology, 4)
        .into_iter()
        .map(|device| InstanceSpec {
            device,
            role: InstanceRole::Colocated,
            slots: 12,
        })
        .collect();
    let mut b = ClusterConfig::builder(topology, instances, CostModel::new(cluster_device(), 0.0));
    b = if cache_aware {
        b.route(RoutePolicy::CacheAware).prefix(agentic_prefix(fabric))
    } else {
        b.route(RoutePolicy::SessionAffinity)
    };
    b.build()
}

/// Agentic deployment + multi-turn workload + arrival window.
#[derive(Debug, Clone)]
pub struct AgenticScenario {
    pub cluster: ClusterConfig,
    pub workload: AgenticWorkload,
    /// Arrival window, virtual seconds (the run drains afterwards).
    pub horizon: f64,
}

/// The checked-in agentic scenario for one (fabric, router) cell.
pub fn agentic_scenario(fabric: ClusterFabric, cache_aware: bool) -> AgenticScenario {
    AgenticScenario {
        cluster: agentic_cluster(fabric, cache_aware),
        workload: agentic_multiturn(AGENTIC_RATES[0]),
        horizon: 8.0,
    }
}

/// Generate the multi-turn workload and run the cluster simulator.
pub fn run_agentic_scenario(sc: &AgenticScenario) -> ClusterReport {
    simulate_cluster(&sc.cluster, &sc.workload.generate(sc.horizon))
}

/// Sweep offered request rate over the agentic scenario, fanned
/// across `sim::sweep` workers (bit-identical to a sequential loop).
/// Thin wrapper over the `rate` [`SweepSpec`](crate::sim::SweepSpec)
/// axis.
pub fn agentic_rate_sweep(
    base: &AgenticScenario,
    rates: &[f64],
    slo: &Slo,
) -> Vec<OperatingPoint> {
    crate::sim::SweepSpec::over("rate", rates.to_vec()).values(|&rate| {
        let mut sc = base.clone();
        sc.workload = sc.workload.with_mean_rate(rate);
        run_agentic_scenario(&sc).operating_point(rate, slo)
    })
}

/// Cache-aware vs cache-blind on one fabric: the headline numbers the
/// scenario test, bench gate, and example all read.
#[derive(Debug, Clone)]
pub struct AgenticSummary {
    /// Max-QPS-under-SLO operating point, `CacheAware` + prefix store.
    pub aware: OperatingPoint,
    /// Max-QPS-under-SLO operating point, cache-blind `SessionAffinity`.
    pub blind: OperatingPoint,
    /// Full report of the aware cell at [`AGENTIC_COMPARE_RATE`].
    pub aware_report: ClusterReport,
    /// Full report of the blind cell at [`AGENTIC_COMPARE_RATE`].
    pub blind_report: ClusterReport,
}

impl AgenticSummary {
    /// Max-QPS-under-SLO gain of cache-aware over cache-blind.
    pub fn qps_gain(&self) -> f64 {
        self.aware.rate / self.blind.rate
    }
}

/// Run the cache-aware vs cache-blind comparison on one fabric.
pub fn agentic_comparison(fabric: ClusterFabric) -> AgenticSummary {
    let cell = |aware: bool| {
        let points = agentic_rate_sweep(
            &agentic_scenario(fabric, aware),
            &AGENTIC_RATES,
            &cluster_slo(),
        );
        max_qps_under_slo(&points)
            .unwrap_or_else(|| panic!("{fabric:?}/aware={aware} must attain at the lowest rate"))
    };
    let report = |aware: bool| {
        let mut sc = agentic_scenario(fabric, aware);
        sc.workload = sc.workload.with_mean_rate(AGENTIC_COMPARE_RATE);
        run_agentic_scenario(&sc)
    };
    AgenticSummary {
        aware: cell(true),
        blind: cell(false),
        aware_report: report(true),
        blind_report: report(false),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::batcher::{simulate, ServingConfig};
    use crate::supernode::{DeviceSpec, Fabric, Geometry};

    fn tiny_kv(pages_at_f0: u64) -> KvCacheConfig {
        KvCacheConfig {
            kv_bytes_per_token: 1024,
            tokens_per_page: 16,
            weight_bytes: 1 << 20,
            hbm_usable: (1 << 20) + pages_at_f0 * 16 * 1024,
            hbm_bw: 1e12,
            pool_bw: 100e9,
            attn_tokens_per_s: 40e6,
        }
    }

    fn fixed_requests(n: u64, prompt: usize, output: usize, spacing: f64) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                tenant: (id % 3) as usize,
                session: id % 3,
                arrival: id as f64 * spacing,
                prompt_tokens: prompt,
                shared_prefix_tokens: 0,
                output_tokens: output,
            })
            .collect()
    }

    fn tiny_topology(fabric: Fabric) -> Topology {
        Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 2,
                dies_per_board: 4,
            },
            fabric,
            DeviceSpec::ascend_910c(),
        )
    }

    fn tiny_cluster(instances: Vec<InstanceSpec>, pages: u64) -> ClusterConfig {
        ClusterConfig::builder(
            tiny_topology(Fabric::supernode()),
            instances,
            CostModel::new(tiny_kv(pages), 0.0),
        )
        .max_seq(512)
        .build()
    }

    fn colocated_spec(slots: usize) -> Vec<InstanceSpec> {
        vec![InstanceSpec {
            device: DeviceId(0),
            role: InstanceRole::Colocated,
            slots,
        }]
    }

    fn disagg_spec() -> Vec<InstanceSpec> {
        vec![
            InstanceSpec {
                device: DeviceId(0),
                role: InstanceRole::Prefill,
                slots: 2,
            },
            InstanceSpec {
                device: DeviceId(4),
                role: InstanceRole::Decode,
                slots: 4,
            },
        ]
    }

    #[test]
    fn single_colocated_instance_matches_the_batcher_bit_for_bit() {
        // tight arrivals exercise the preemption path in both
        let reqs = fixed_requests(30, 48, 12, 1e-5);
        let cluster = tiny_cluster(colocated_spec(6), 16);
        let crep = simulate_cluster(&cluster, &reqs);
        let brep = simulate(
            &ServingConfig {
                fleet: 1,
                slots: 6,
                max_seq: 512,
                cost: CostModel::new(tiny_kv(16), 0.0),
                policy: MemoryPolicy::NoOffload,
                pool_pages: 0,
                max_preemptions: 4,
                trace_mode: TraceMode::Indexed,
            },
            &reqs,
        );
        assert_eq!(crep.serving.makespan.to_bits(), brep.makespan.to_bits());
        assert_eq!(crep.serving.rejected, brep.rejected);
        assert_eq!(crep.serving.preemptions, brep.preemptions);
        assert_eq!(crep.serving.outcomes.len(), brep.outcomes.len());
        for (a, b) in crep.serving.outcomes.iter().zip(&brep.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.first_token.to_bits(), b.first_token.to_bits());
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
        }
        assert_eq!(crep.kv_migrations, 0, "colocated never migrates");
        assert_eq!(crep.crashes, 0);
        assert_eq!(crep.scale_ups, 0);
        // a static cluster holds its device for the whole run
        assert_eq!(
            crep.instance_seconds.to_bits(),
            crep.serving.makespan.to_bits()
        );
    }

    #[test]
    fn disaggregated_migrates_every_multi_token_request_once() {
        let reqs = fixed_requests(12, 40, 8, 0.02);
        let rep = simulate_cluster(&tiny_cluster(disagg_spec(), 64), &reqs);
        assert_eq!(rep.serving.rejected, 0);
        assert_eq!(rep.completed(), 12);
        assert_eq!(rep.kv_migrations, 12);
        assert!(rep.kv_bytes_migrated > 0.0);
        assert!(rep.kv_xfer_time > 0.0);
        // trace: prefill work on instance 0, decode + kv_xfer on 1
        let trace = &rep.serving.trace;
        assert_eq!(trace.resources(), 2);
        assert!(trace.tagged_count(tags::KV_XFER) >= 12);
        assert!(trace.tagged_count(tags::PREFILL) > 0);
        assert!(trace.tagged_count(tags::DECODE) > 0);
        for iv in trace.intervals_tagged(tags::KV_XFER) {
            assert_eq!(iv.resource, ResourceId(1), "xfer staged on the decode engine");
        }
        // outcomes carry full token counts and a prefill-side TTFT
        for o in &rep.serving.outcomes {
            assert_eq!(o.output_tokens, 8);
            assert!(o.first_token > o.arrival);
            assert!(o.finish > o.first_token);
        }
        assert_eq!(rep.per_instance_completed, vec![0, 12]);
    }

    #[test]
    fn single_token_outputs_complete_at_prefill_without_migrating() {
        let reqs = fixed_requests(6, 32, 1, 0.05);
        let rep = simulate_cluster(&tiny_cluster(disagg_spec(), 64), &reqs);
        assert_eq!(rep.completed(), 6);
        assert_eq!(rep.kv_migrations, 0);
        assert_eq!(rep.per_instance_completed, vec![6, 0]);
        for o in &rep.serving.outcomes {
            assert_eq!(o.output_tokens, 1);
        }
    }

    #[test]
    fn oversized_prompt_rejected_not_deadlocked() {
        // 4 HBM pages = 64 tokens; a 100-token prompt can never fit
        let mut reqs = fixed_requests(3, 16, 4, 0.01);
        reqs[1].prompt_tokens = 100;
        let rep = simulate_cluster(&tiny_cluster(disagg_spec(), 4), &reqs);
        assert_eq!(rep.serving.rejected, 1);
        assert_eq!(rep.completed(), 2);
    }

    #[test]
    fn deterministic_bit_identical_reruns() {
        let reqs = fixed_requests(25, 48, 10, 1e-4);
        let cfg = tiny_cluster(disagg_spec(), 24);
        let a = simulate_cluster(&cfg, &reqs);
        let b = simulate_cluster(&cfg, &reqs);
        assert_eq!(a.serving.makespan.to_bits(), b.serving.makespan.to_bits());
        assert_eq!(a.kv_migrations, b.kv_migrations);
        assert_eq!(a.serving.outcomes.len(), b.serving.outcomes.len());
        for (x, y) in a.serving.outcomes.iter().zip(&b.serving.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }

    #[test]
    fn migration_cost_follows_the_fabric() {
        // prefill on rack 0, decode on rack 1: migrations pay the
        // cross-rack tier, where the fabrics differ most
        let two_rack = |fabric| {
            Topology::new(
                Geometry {
                    racks: 2,
                    boards_per_rack: 1,
                    dies_per_board: 4,
                },
                fabric,
                DeviceSpec::ascend_910c(),
            )
        };
        let reqs = fixed_requests(12, 40, 8, 0.02);
        let mut cfg = tiny_cluster(disagg_spec(), 64);
        cfg.topology = two_rack(Fabric::supernode());
        let sn = simulate_cluster(&cfg, &reqs);
        cfg.topology = two_rack(Fabric::legacy());
        let lg = simulate_cluster(&cfg, &reqs);
        assert_eq!(sn.kv_migrations, lg.kv_migrations);
        assert!(
            lg.kv_xfer_time > 5.0 * sn.kv_xfer_time,
            "legacy cross-rack tier must be far slower: {} vs {}",
            lg.kv_xfer_time,
            sn.kv_xfer_time
        );
    }

    #[test]
    fn accounting_adds_up_under_pressure() {
        // undersized decode pool: preemptions + backpressure exercised
        let reqs = fixed_requests(40, 48, 12, 1e-4);
        let rep = simulate_cluster(&tiny_cluster(disagg_spec(), 16), &reqs);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 40);
        let produced: u64 = rep
            .serving
            .outcomes
            .iter()
            .map(|o| o.output_tokens as u64)
            .sum();
        assert!(rep.serving.decoded_tokens >= produced);
        // per-resource intervals never overlap (engine serializes
        // iterations and staged ingests)
        for r in 0..rep.serving.trace.resources() {
            let bucket = rep.serving.trace.per_resource(ResourceId(r));
            assert!(bucket.windows(2).all(|w| w[0].finish <= w[1].start + 1e-12));
        }
    }

    #[test]
    fn round_robin_routing_spreads_colocated_arrivals() {
        let mut cfg = tiny_cluster(
            vec![
                InstanceSpec {
                    device: DeviceId(0),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
                InstanceSpec {
                    device: DeviceId(1),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
            ],
            64,
        );
        cfg.route = RoutePolicy::RoundRobin;
        let reqs = fixed_requests(20, 32, 6, 0.01);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.completed(), 20);
        assert_eq!(rep.per_instance_completed, vec![10, 10]);
    }

    #[test]
    fn spread_placement_crosses_racks() {
        let topo = Topology::matrix384();
        let places = spread_placement(&topo, 4);
        assert_eq!(places.len(), 4);
        for (i, &a) in places.iter().enumerate() {
            for &b in &places[i + 1..] {
                assert_ne!(a, b);
                assert_eq!(
                    topo.tier_between(a, b),
                    crate::supernode::LinkTier::CrossRack
                );
            }
        }
        let legacy = Topology::legacy_cluster(32);
        for (i, &a) in spread_placement(&legacy, 4).iter().enumerate() {
            for &b in &spread_placement(&legacy, 4)[i + 1..] {
                assert_eq!(
                    legacy.tier_between(a, b),
                    crate::supernode::LinkTier::CrossRack
                );
            }
        }
    }

    // ---- ISSUE 4 satellite: placement guards ---------------------------

    #[test]
    fn spread_placement_never_duplicates_devices() {
        // regression: on a 1-rack/2-board/4-die topology the old
        // formula wrapped back to device 0 at the third instance,
        // silently co-locating instances on one chip
        let topo = tiny_topology(Fabric::supernode());
        for n in 1..=topo.device_count() {
            let places = spread_placement(&topo, n);
            assert_eq!(places.len(), n);
            let distinct: BTreeSet<DeviceId> = places.iter().copied().collect();
            assert_eq!(distinct.len(), n, "duplicate device at n={n}: {places:?}");
            for &d in &places {
                assert!(d.0 < topo.device_count());
            }
        }
    }

    #[test]
    fn spread_placement_clamps_and_try_variant_errors() {
        let topo = tiny_topology(Fabric::supernode());
        let total = topo.device_count();
        // asking for more instances than chips clamps to the chip count
        let places = spread_placement(&topo, total + 5);
        assert_eq!(places.len(), total);
        let distinct: BTreeSet<DeviceId> = places.iter().copied().collect();
        assert_eq!(distinct.len(), total);
        // the fallible form reports the overflow instead
        assert!(try_spread_placement(&topo, total).is_ok());
        let err = try_spread_placement(&topo, total + 1).unwrap_err();
        assert!(err.contains("8 devices"), "err: {err}");
        assert!(try_spread_placement(&topo, 0).unwrap().is_empty());
    }

    // ---- ISSUE 4: elasticity and failure -------------------------------

    fn elastic_cluster(
        instances: Vec<InstanceSpec>,
        pages: u64,
        policy: AutoscalePolicy,
        pool: Vec<DeviceId>,
        max: usize,
    ) -> ClusterConfig {
        let mut cfg = tiny_cluster(instances, pages);
        cfg.autoscale = Some(AutoscaleConfig {
            policy,
            eval_interval: 0.005,
            min_instances: 1,
            max_instances: max,
            slots: 4,
            up_cooldown: 0.0,
            down_cooldown: 0.01,
            lookback: 0.5,
            device_pool: pool,
        });
        cfg
    }

    #[test]
    fn scheduled_scale_up_pays_warmup_then_serves() {
        // one overloaded instance, schedule demands three from t=0.02:
        // two spawns, each paying the weight transfer before admitting
        // anything; the backlog then spreads onto the new engines
        let cfg = elastic_cluster(
            colocated_spec(4),
            64,
            AutoscalePolicy::Scheduled {
                steps: vec![(0.0, 1), (0.02, 3)],
            },
            vec![DeviceId(1), DeviceId(2)],
            4,
        );
        let reqs = fixed_requests(200, 32, 8, 2e-4);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 200);
        assert_eq!(rep.scale_ups, 2);
        assert_eq!(rep.crashes, 0);
        assert!(rep.warmup_time > 0.0);
        let trace = &rep.serving.trace;
        assert_eq!(trace.resources(), 3);
        assert_eq!(trace.tagged_count(tags::WARMUP), 2);
        // warmup occupies the new engines before any of their work
        for iv in trace.intervals_tagged(tags::WARMUP) {
            assert!(iv.resource.0 >= 1);
            assert!(iv.finish > iv.start);
            for other in trace.per_resource(iv.resource) {
                assert!(other.start >= iv.start);
            }
        }
        // the spawned instances actually served requests
        assert!(rep.per_instance_completed[1] + rep.per_instance_completed[2] > 0);
        assert_eq!(rep.peak_instances, 3);
        assert!(rep.instance_seconds < 3.0 * rep.serving.makespan);
    }

    #[test]
    fn scheduled_scale_down_drains_migrates_and_releases() {
        // start with three instances, drop to one at t=0.02 while work
        // is still in flight: queued + resident sequences must migrate
        // out under the custody protocol, then the devices release
        let cfg = elastic_cluster(
            vec![
                InstanceSpec {
                    device: DeviceId(0),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
                InstanceSpec {
                    device: DeviceId(1),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
                InstanceSpec {
                    device: DeviceId(2),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
            ],
            64,
            AutoscalePolicy::Scheduled {
                steps: vec![(0.0, 3), (0.02, 1)],
            },
            vec![],
            3,
        );
        let reqs = fixed_requests(200, 32, 8, 2e-4);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 200);
        assert_eq!(rep.scale_downs, 2);
        assert!(rep.drain_migrations > 0, "resident KV must migrate out");
        assert!(rep.kv_migrations >= rep.drain_migrations);
        let trace = &rep.serving.trace;
        assert_eq!(trace.tagged_count(tags::DRAIN), 2, "both devices released");
        // released instances stop accruing instance-seconds
        assert!(rep.instance_seconds < 3.0 * rep.serving.makespan);
        // conservation held (simulate_cluster asserts pools drained)
        let ids: BTreeSet<u64> = rep.serving.outcomes.iter().map(|o| o.id).collect();
        assert_eq!(ids.len(), rep.completed(), "no duplicate completions");
    }

    #[test]
    fn crash_requeues_in_flight_work_and_loses_nothing() {
        // two colocated instances, no autoscaler: kill one mid-run;
        // its in-flight and queued requests re-prefill on the survivor
        let mut cfg = tiny_cluster(
            vec![
                InstanceSpec {
                    device: DeviceId(0),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
                InstanceSpec {
                    device: DeviceId(1),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
            ],
            64,
        );
        cfg.failures = vec![InstanceCrash {
            time: 0.03,
            instance: 0,
        }];
        let reqs = fixed_requests(40, 32, 10, 0.002);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.crashes, 1);
        assert!(rep.crash_requeues > 0, "victim held in-flight work");
        assert_eq!(
            rep.completed() as u64 + rep.serving.rejected,
            40,
            "crash must not lose requests"
        );
        assert_eq!(rep.serving.rejected, 0, "survivor has room for everything");
        let trace = &rep.serving.trace;
        assert_eq!(trace.tagged_count(tags::CRASH), 1);
        for iv in trace.intervals_tagged(tags::CRASH) {
            assert!(iv.finish <= 0.03 + 1e-12, "lost work truncated at death");
        }
        // the dead engine does no work after the crash
        for iv in rep.serving.trace.per_resource(ResourceId(0)) {
            assert!(iv.start <= 0.03 + 1e-12);
        }
        // requeued requests kept their first-token continuity: TTFT of
        // every outcome is still well-defined and positive
        for o in &rep.serving.outcomes {
            assert!(o.first_token > o.arrival);
        }
    }

    #[test]
    fn crash_of_sole_instance_with_autoscaler_recovers_via_replacement() {
        // the only instance dies; the autoscaler spawns a replacement
        // immediately and arrivals during the warm-up wait in limbo
        let cfg = {
            let mut c = elastic_cluster(
                colocated_spec(4),
                64,
                // schedule holds the size at 1: only crash replacement spawns
                AutoscalePolicy::Scheduled {
                    steps: vec![(0.0, 1)],
                },
                vec![DeviceId(1)],
                2,
            );
            c.failures = vec![InstanceCrash {
                time: 0.02,
                instance: 0,
            }];
            c
        };
        let reqs = fixed_requests(30, 32, 8, 0.003);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.crashes, 1);
        assert_eq!(rep.scale_ups, 1, "replacement spawned at the crash");
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 30);
        assert_eq!(rep.serving.rejected, 0, "limbo holds arrivals, not drops");
        assert!(rep.per_instance_completed[1] > 0, "replacement served");
        assert_eq!(rep.serving.trace.tagged_count(tags::WARMUP), 1);
    }

    #[test]
    fn crash_without_capacity_rejects_instead_of_hanging() {
        // no autoscaler, single instance: a crash strands everything
        // still in flight — requests must be rejected, never lost
        let mut cfg = tiny_cluster(colocated_spec(4), 64);
        cfg.failures = vec![InstanceCrash {
            time: 0.02,
            instance: 0,
        }];
        let reqs = fixed_requests(30, 32, 8, 0.003);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.crashes, 1);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 30);
        assert!(rep.serving.rejected > 0, "no capacity left: must reject");
    }

    #[test]
    fn ordinal_crash_targeting_hits_a_live_instance() {
        // crash ordinal 5 of a 2-instance cluster: 5 mod 2 = instance 1
        let mut cfg = tiny_cluster(
            vec![
                InstanceSpec {
                    device: DeviceId(0),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
                InstanceSpec {
                    device: DeviceId(1),
                    role: InstanceRole::Colocated,
                    slots: 4,
                },
            ],
            64,
        );
        cfg.failures = vec![InstanceCrash {
            time: 0.02,
            instance: 5,
        }];
        let reqs = fixed_requests(30, 32, 8, 0.002);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.crashes, 1);
        for iv in rep.serving.trace.intervals_tagged(tags::CRASH) {
            assert_eq!(iv.resource, ResourceId(1), "5 mod 2 targets instance 1");
        }
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 30);
    }

    #[test]
    fn queue_depth_policy_tracks_a_load_step_end_to_end() {
        // a burst of tight arrivals followed by a lull: the queue-depth
        // policy scales up into the burst and back down after it
        let cfg = elastic_cluster(
            colocated_spec(4),
            64,
            AutoscalePolicy::QueueDepth {
                scale_up_backlog: 0.8,
                scale_down_backlog: 0.7,
            },
            vec![DeviceId(1), DeviceId(2), DeviceId(3)],
            4,
        );
        let mut reqs = fixed_requests(80, 32, 8, 0.0005);
        // a late straggler keeps the run alive through the lull so the
        // scale-down has time to trigger
        reqs.push(Request {
            id: 80,
            tenant: 0,
            session: 0,
            arrival: 0.5,
            prompt_tokens: 32,
            shared_prefix_tokens: 0,
            output_tokens: 8,
        });
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 81);
        assert!(rep.scale_ups >= 1, "burst must trigger a scale-up");
        assert!(rep.scale_downs >= 1, "lull must trigger a scale-down");
        assert!(rep.serving.trace.tagged_count(tags::WARMUP) >= 1);
        assert!(rep.serving.trace.tagged_count(tags::DRAIN) >= 1);
    }

    #[test]
    fn disaggregated_autoscaler_scales_the_decode_pool() {
        // disagg cluster under decode pressure: the scaled role is the
        // decode pool, prefill instances are left alone
        let mut cfg = tiny_cluster(disagg_spec(), 64);
        cfg.autoscale = Some(AutoscaleConfig {
            policy: AutoscalePolicy::Scheduled {
                steps: vec![(0.0, 1), (0.01, 2)],
            },
            eval_interval: 0.005,
            min_instances: 1,
            max_instances: 2,
            slots: 4,
            up_cooldown: 0.0,
            down_cooldown: 0.01,
            lookback: 0.5,
            device_pool: vec![DeviceId(5)],
        });
        // long outputs keep the decode pool saturated, so migrations
        // spill onto the new member once it is up
        let reqs = fixed_requests(40, 40, 64, 8e-4);
        let rep = simulate_cluster(&cfg, &reqs);
        assert_eq!(rep.completed() as u64 + rep.serving.rejected, 40);
        assert_eq!(rep.scale_ups, 1);
        assert_eq!(rep.serving.trace.resources(), 3);
        // the new decode instance received migrations and completed work
        assert!(rep.per_instance_completed[2] > 0, "new decode member served");
        assert_eq!(
            rep.per_instance_completed[0], 0,
            "prefill pool still completes nothing"
        );
    }

    #[test]
    fn single_pool_fleet_cluster_is_bit_identical() {
        // wrapping the crossover topology in a degenerate one-pool
        // fleet must not perturb a single bit of the report
        let base = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
        let mut with_fleet = base.clone();
        with_fleet.cluster.fleet = Some(Fleet::single(base.cluster.topology.clone()));
        let a = run_cluster_scenario(&base);
        let b = run_cluster_scenario(&with_fleet);
        assert_eq!(a.kv_xfer_time.to_bits(), b.kv_xfer_time.to_bits());
        assert_eq!(a.serving.makespan.to_bits(), b.serving.makespan.to_bits());
        assert_eq!(a.summary_kv(), b.summary_kv());
    }

    #[test]
    fn fleet_aware_prefill_beats_cross_supernode_split() {
        let aware = run_cluster_scenario(&fleet_prefill_scenario(true));
        let naive = run_cluster_scenario(&fleet_prefill_scenario(false));
        assert!(aware.completed() > 0, "aware cell must serve traffic");
        assert!(naive.completed() > 0, "naive cell must serve traffic");
        assert!(aware.kv_migrations > 0 && naive.kv_migrations > 0);
        // every naive handoff crosses the DCN link (~5.2 ms vs
        // ~1.3 ms local); expected ratio ≈ 3.9x, gated with margin
        assert!(
            naive.kv_xfer_time > 2.0 * aware.kv_xfer_time,
            "aware={} naive={}",
            aware.kv_xfer_time,
            naive.kv_xfer_time
        );
    }
}
