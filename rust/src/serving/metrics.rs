//! SLO metrics and operating-point sweeps for the serving simulator.
//!
//! Definitions (all in virtual seconds):
//!
//! - **TTFT** — time to first token, `first_token - arrival` (queue
//!   wait + prefill). Preserved across recompute-preemption: the
//!   client saw the stream start once.
//! - **TPOT** — time per output token after the first,
//!   `(finish - first_token) / (output - 1)`.
//! - **Goodput** — completed requests that individually met the SLO,
//!   per second of makespan.
//! - A configuration **attains** an SLO when it rejected nothing and
//!   its p99 TTFT/TPOT are within bounds; the **max-QPS-under-SLO
//!   operating point** is the highest offered rate that attains.
//!
//! Sweeps over arrival rate (and fleet size / offload fraction in the
//! `serve_sweep` example) fan out through `sim::sweep::parallel_map` —
//! the simulator is deterministic, so sweep results are bit-identical
//! to sequential runs and comparable across machines, which is what
//! lets CI gate on them (`tools/bench_regression.py`).

use crate::hyperoffload::kvcache::KvCacheConfig;
use crate::serving::batcher::{simulate, CostModel, ServingConfig};
use crate::serving::memory::MemoryPolicy;
use crate::serving::workload::{ArrivalProcess, LengthDist, WorkloadConfig};
use crate::sim::{Trace, TraceMode};
use crate::util::stats::Percentiles;

/// One completed request with its timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestOutcome {
    pub id: u64,
    pub tenant: usize,
    pub arrival: f64,
    pub first_token: f64,
    pub finish: f64,
    /// Prompt length after clamping to the sequence budget.
    pub prompt_tokens: usize,
    /// Tokens actually produced.
    pub output_tokens: usize,
    pub preemptions: u32,
}

impl RequestOutcome {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    pub fn tpot(&self) -> f64 {
        if self.output_tokens > 1 {
            (self.finish - self.first_token) / (self.output_tokens - 1) as f64
        } else {
            0.0
        }
    }

    pub fn e2e(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// Latency service-level objective on the p99s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    pub ttft_p99: f64,
    pub tpot_p99: f64,
}

impl Slo {
    /// Did this single request meet the per-request bounds?
    pub fn met_by(&self, o: &RequestOutcome) -> bool {
        o.ttft() <= self.ttft_p99 && o.tpot() <= self.tpot_p99
    }
}

/// Everything a serving run produced.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub outcomes: Vec<RequestOutcome>,
    /// Requests dropped (prompt could never fit, or preemption budget
    /// exhausted).
    pub rejected: u64,
    pub preemptions: u64,
    /// HBM→pool page demotions across the fleet.
    pub demotions: u64,
    pub decoded_tokens: u64,
    pub prefill_tokens: u64,
    /// High-water mark of concurrently admitted context tokens across
    /// the fleet — the serving-side "supported context" axis.
    pub peak_context_tokens: usize,
    pub makespan: f64,
    /// Per-replica busy intervals — CSR-indexed under
    /// [`TraceMode::Indexed`], accumulator-only (no interval log) under
    /// [`TraceMode::Streaming`]. Every summary statistic below works in
    /// both modes.
    pub trace: Trace,
}

impl ServingReport {
    pub fn completed(&self) -> usize {
        self.outcomes.len()
    }

    /// Completed requests per second of makespan.
    pub fn admitted_qps(&self) -> f64 {
        if self.makespan > 0.0 {
            self.completed() as f64 / self.makespan
        } else {
            0.0
        }
    }

    fn percentile(&self, p: f64, f: impl Fn(&RequestOutcome) -> f64) -> f64 {
        let mut pct = Percentiles::new();
        for o in &self.outcomes {
            pct.add(f(o));
        }
        pct.pct(p)
    }

    pub fn ttft_pct(&self, p: f64) -> f64 {
        self.percentile(p, RequestOutcome::ttft)
    }

    pub fn tpot_pct(&self, p: f64) -> f64 {
        self.percentile(p, RequestOutcome::tpot)
    }

    pub fn e2e_pct(&self, p: f64) -> f64 {
        self.percentile(p, RequestOutcome::e2e)
    }

    /// SLO-meeting completions per second of makespan.
    pub fn goodput(&self, slo: &Slo) -> f64 {
        if self.makespan > 0.0 {
            self.outcomes.iter().filter(|o| slo.met_by(o)).count() as f64 / self.makespan
        } else {
            0.0
        }
    }

    /// Whole-run SLO attainment: nothing rejected, p99s in bounds.
    pub fn attains(&self, slo: &Slo) -> bool {
        !self.outcomes.is_empty()
            && self.rejected == 0
            && self.ttft_pct(99.0) <= slo.ttft_p99
            && self.tpot_pct(99.0) <= slo.tpot_p99
    }

    /// p99 TTFT over only the requests that *arrived* in `[lo, hi)` —
    /// the windowed view the crash-recovery scenario asserts on: after
    /// an instance crash, requests arriving once the replacement is up
    /// must meet the SLO again even though the crash-window requests
    /// dragged the whole-run percentile up.
    pub fn ttft_pct_arriving_in(&self, p: f64, lo: f64, hi: f64) -> f64 {
        let mut pct = Percentiles::new();
        for o in &self.outcomes {
            if o.arrival >= lo && o.arrival < hi {
                pct.add(o.ttft());
            }
        }
        pct.pct(p)
    }

    /// Mean replica utilization over the makespan.
    pub fn mean_utilization(&self) -> f64 {
        self.trace.mean_utilization_all()
    }

    /// The serving summary rows every bench/example emission flows
    /// through — one definition of the key set, shared (and extended)
    /// by the cluster and co-schedule reports, so emitted metric names
    /// can't drift between consumers.
    pub fn summary_kv(&self) -> Vec<(String, f64)> {
        let push = |k: &str, v: f64| (k.to_string(), v);
        vec![
            push("completed", self.completed() as f64),
            push("rejected", self.rejected as f64),
            push("preemptions", self.preemptions as f64),
            push("demotions", self.demotions as f64),
            push("decoded_tokens", self.decoded_tokens as f64),
            push("prefill_tokens", self.prefill_tokens as f64),
            push("peak_context_tokens", self.peak_context_tokens as f64),
            push("makespan", self.makespan),
            push("admitted_qps", self.admitted_qps()),
            push("p50_ttft", self.ttft_pct(50.0)),
            push("p99_ttft", self.ttft_pct(99.0)),
            push("p99_tpot", self.tpot_pct(99.0)),
            push("mean_utilization", self.mean_utilization()),
        ]
    }

    /// Condense the run into a sweep row. Builds each latency
    /// distribution once and reads every percentile (and the SLO
    /// verdict) from it, instead of re-sorting per query.
    pub fn operating_point(&self, rate: f64, slo: &Slo) -> OperatingPoint {
        let mut ttft = Percentiles::new();
        let mut tpot = Percentiles::new();
        for o in &self.outcomes {
            ttft.add(o.ttft());
            tpot.add(o.tpot());
        }
        let p99_ttft = ttft.pct(99.0);
        let p99_tpot = tpot.pct(99.0);
        let attains_slo = !self.outcomes.is_empty()
            && self.rejected == 0
            && p99_ttft <= slo.ttft_p99
            && p99_tpot <= slo.tpot_p99;
        OperatingPoint {
            rate,
            completed: self.completed(),
            rejected: self.rejected,
            admitted_qps: self.admitted_qps(),
            goodput: self.goodput(slo),
            p50_ttft: ttft.pct(50.0),
            p99_ttft,
            p99_tpot,
            mean_utilization: self.mean_utilization(),
            peak_context_tokens: self.peak_context_tokens,
            preemptions: self.preemptions,
            demotions: self.demotions,
            attains_slo,
        }
    }
}

/// Route the inherent rows through the shared bench-emission trait
/// (the inherent method stays for direct callers; inherent methods
/// take precedence, so this delegation does not recurse).
impl crate::util::summary::SummaryKv for ServingReport {
    fn summary_kv(&self) -> Vec<(String, f64)> {
        ServingReport::summary_kv(self)
    }
}

/// One row of a rate sweep.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    /// Offered cluster-wide arrival rate, requests/second.
    pub rate: f64,
    pub completed: usize,
    pub rejected: u64,
    pub admitted_qps: f64,
    pub goodput: f64,
    pub p50_ttft: f64,
    pub p99_ttft: f64,
    pub p99_tpot: f64,
    pub mean_utilization: f64,
    pub peak_context_tokens: usize,
    pub preemptions: u64,
    pub demotions: u64,
    pub attains_slo: bool,
}

/// A full scenario: deployment + workload + how long arrivals flow.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub serving: ServingConfig,
    pub workload: WorkloadConfig,
    /// Arrival window, virtual seconds (the run drains afterwards).
    pub horizon: f64,
}

/// Generate the workload and run the simulator.
pub fn run_scenario(sc: &Scenario) -> ServingReport {
    simulate(&sc.serving, &sc.workload.generate(sc.horizon))
}

/// Sweep offered load: rescale the scenario's arrival process to each
/// rate and simulate, fanned across `sim::sweep` workers. Results are
/// in input order and bit-identical to a sequential loop. Thin
/// wrapper over the `rate` [`SweepSpec`](crate::sim::SweepSpec) axis.
pub fn rate_sweep(base: &Scenario, rates: &[f64], slo: &Slo) -> Vec<OperatingPoint> {
    crate::sim::SweepSpec::over("rate", rates.to_vec()).values(|&rate| {
        let mut sc = base.clone();
        sc.workload.arrival = sc.workload.arrival.with_mean_rate(rate);
        run_scenario(&sc).operating_point(rate, slo)
    })
}

/// The max-QPS-under-SLO operating point of a sweep, if any rate
/// attained the SLO.
pub fn max_qps_under_slo(points: &[OperatingPoint]) -> Option<OperatingPoint> {
    points
        .iter()
        .filter(|p| p.attains_slo)
        .max_by(|a, b| a.rate.total_cmp(&b.rate))
        .copied()
}

// ---- shared scenario presets (tests, bench, example) -----------------

/// Scaled-down Llama-8B-class device for CI-sized serving scenarios:
/// the bandwidth ratios of `KvCacheConfig::llama8b_910c`, but an HBM
/// that fits only 4K KV tokens beyond the weights, so multi-tenant
/// memory pressure appears at toy request counts.
pub fn smoke_device() -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 131_072,
        tokens_per_page: 64,
        weight_bytes: 8 * (1u64 << 30),
        hbm_usable: 8 * (1u64 << 30) + 4096 * 131_072,
        hbm_bw: 1.6e12,
        pool_bw: 392e9,
        attn_tokens_per_s: 40e6,
    }
}

/// Reference smoke scenario: Poisson arrivals, log-normal prompts,
/// `offload_frac > 0` enables the pool policy. Used identically by the
/// scenario tests, `bench_serving` (whose deterministic metrics CI
/// gates on), and the `serve_sweep` example — one definition, three
/// consumers, so the gate can never drift from what the tests assert.
pub fn smoke_scenario(rate: f64, offload_frac: f64, fleet: usize) -> Scenario {
    let policy = if offload_frac > 0.0 {
        MemoryPolicy::PoolOffload
    } else {
        MemoryPolicy::NoOffload
    };
    Scenario {
        serving: ServingConfig {
            fleet,
            slots: 16,
            max_seq: 2048,
            cost: CostModel::new(smoke_device(), offload_frac),
            policy,
            pool_pages: 4096,
            max_preemptions: 4,
            trace_mode: TraceMode::Indexed,
        },
        workload: WorkloadConfig {
            arrival: ArrivalProcess::Poisson { rate },
            prompt: LengthDist::LogNormal {
                mu: 6.2,
                sigma: 0.35,
                cap: 1200,
            },
            output: LengthDist::Uniform { lo: 24, hi: 40 },
            seed: 42,
        },
        horizon: 8.0,
    }
}

/// City-scale scenario: a 1024-replica fleet under sustained Poisson
/// load for 60 virtual seconds — ≥10^5 requests and ≥10^7 engine
/// events (every batcher iteration is one interval). Infeasible on the
/// in-memory interval log (10^7 × 40-byte intervals plus the CSR
/// permutation and prefix arrays), so the preset hard-wires
/// [`TraceMode::Streaming`]; memory stays bounded by the accumulators
/// (O(fleet + tags)). Run by `tests/scale_smoke.rs` and the CI
/// `scale-smoke` job in release mode under a wall-clock timeout.
pub fn city_scale_scenario() -> Scenario {
    Scenario {
        serving: ServingConfig {
            fleet: 1024,
            slots: 16,
            max_seq: 2048,
            cost: CostModel::new(smoke_device(), 0.2),
            policy: MemoryPolicy::PoolOffload,
            pool_pages: 4096,
            max_preemptions: 4,
            trace_mode: TraceMode::Streaming,
        },
        workload: WorkloadConfig {
            arrival: ArrivalProcess::Poisson { rate: 2400.0 },
            prompt: LengthDist::LogNormal {
                mu: 6.2,
                sigma: 0.35,
                cap: 1200,
            },
            output: LengthDist::Uniform { lo: 96, hi: 160 },
            seed: 42,
        },
        horizon: 60.0,
    }
}

/// The smoke scenarios' SLO: 300 ms to first token, 15 ms/token after.
pub fn smoke_slo() -> Slo {
    Slo {
        ttft_p99: 0.3,
        tpot_p99: 0.015,
    }
}

/// The rate grid the smoke comparison runs on (cluster-wide QPS for a
/// 2-replica fleet). Fixed so the CI regression gate compares the same
/// deterministic sweep on every machine.
pub const SMOKE_RATES: [f64; 8] = [15.0, 30.0, 45.0, 60.0, 75.0, 90.0, 105.0, 120.0];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_latency_definitions() {
        let o = RequestOutcome {
            id: 0,
            tenant: 0,
            arrival: 1.0,
            first_token: 1.25,
            finish: 2.25,
            prompt_tokens: 100,
            output_tokens: 11,
            preemptions: 0,
        };
        assert!((o.ttft() - 0.25).abs() < 1e-12);
        assert!((o.tpot() - 0.1).abs() < 1e-12);
        assert!((o.e2e() - 1.25).abs() < 1e-12);
        let slo = Slo {
            ttft_p99: 0.3,
            tpot_p99: 0.15,
        };
        assert!(slo.met_by(&o));
        assert!(!Slo {
            ttft_p99: 0.2,
            tpot_p99: 0.15
        }
        .met_by(&o));
    }

    #[test]
    fn single_token_output_has_zero_tpot() {
        let o = RequestOutcome {
            id: 0,
            tenant: 0,
            arrival: 0.0,
            first_token: 0.1,
            finish: 0.1,
            prompt_tokens: 8,
            output_tokens: 1,
            preemptions: 0,
        };
        assert_eq!(o.tpot(), 0.0);
    }

    #[test]
    fn smoke_scenario_runs_and_reports() {
        let rep = run_scenario(&smoke_scenario(20.0, 0.0, 2));
        assert!(rep.completed() > 50, "completed={}", rep.completed());
        assert!(rep.makespan > 0.0);
        assert!(rep.ttft_pct(50.0) > 0.0);
        assert!(rep.ttft_pct(99.0) >= rep.ttft_pct(50.0));
        assert!(rep.mean_utilization() > 0.0);
        assert!(rep.peak_context_tokens > 0);
    }

    #[test]
    fn rate_sweep_is_parallel_safe_and_ordered() {
        let sc = smoke_scenario(15.0, 0.0, 1);
        let rates = [5.0, 10.0];
        let slo = smoke_slo();
        let par = rate_sweep(&sc, &rates, &slo);
        assert_eq!(par.len(), 2);
        assert_eq!(par[0].rate, 5.0);
        assert_eq!(par[1].rate, 10.0);
        // deterministic: rerunning one point reproduces the sweep row
        let mut one = sc.clone();
        one.workload.arrival = one.workload.arrival.with_mean_rate(10.0);
        let rep = run_scenario(&one).operating_point(10.0, &slo);
        assert_eq!(rep, par[1]);
    }

    #[test]
    fn max_qps_picks_highest_attaining() {
        let mk = |rate: f64, ok: bool| OperatingPoint {
            rate,
            completed: 1,
            rejected: 0,
            admitted_qps: rate,
            goodput: rate,
            p50_ttft: 0.01,
            p99_ttft: 0.02,
            p99_tpot: 0.005,
            mean_utilization: 0.5,
            peak_context_tokens: 100,
            preemptions: 0,
            demotions: 0,
            attains_slo: ok,
        };
        let pts = [mk(10.0, true), mk(20.0, true), mk(30.0, false)];
        assert_eq!(max_qps_under_slo(&pts).unwrap().rate, 20.0);
        let none = [mk(10.0, false)];
        assert!(max_qps_under_slo(&none).is_none());
    }
}
