//! Front-end request router for multi-instance serving.
//!
//! The cluster simulator places N batcher instances on
//! `supernode::Topology` devices; arrivals enter through a router that
//! assigns each request to an instance under a pluggable policy:
//!
//! - **RoundRobin** — stateless baseline, ignores load entirely;
//! - **LeastOutstandingKv** — the KV-aware policy: pick the instance
//!   with the fewest outstanding KV pages (pages held in its
//!   `PagePool` plus pages the queued requests will need). Serving
//!   load is KV-page pressure, not request count, so this beats
//!   least-requests when prompt lengths are heavy-tailed;
//! - **SessionAffinity** — hash the session to a fixed instance, the
//!   prefix-cache-friendly policy: all turns of one session land
//!   where its KV prefix already lives. Only sensible for
//!   many-session workloads — a single hot session saturates its
//!   pinned instance by design;
//! - **CacheAware** — SessionAffinity extended with the fleet-wide
//!   prefix store's knowledge: candidates are scored by expected
//!   prefix-hit pages *net of* outstanding-KV load, so a request
//!   follows its cached prefix unless that instance is swamped.
//!   Sessions with no cached prefix anywhere fall back to the
//!   session-affinity hash, and exclusions (drains, crashes, retry
//!   re-routes) filter the candidate set exactly like every other
//!   policy.
//!
//! The same `Router` is reused for decode-target selection in
//! disaggregated mode (there the policy is always
//! least-outstanding-KV: the KV pages are about to move to that
//! instance, so page headroom is the only signal that matters).
//!
//! Under elasticity the candidate set is *dynamic*: the cluster passes
//! only instances currently in the Serving state, so warming-up,
//! draining, released, and crashed instances never receive work. The
//! router is deliberately stateless about membership — `RoundRobin`
//! cycles over whatever set it is handed (its counter survives set
//! changes), and `SessionAffinity` hashes into the current set, which
//! means a scale event re-pins sessions the way consistent-hashing
//! front-ends rebalance on membership change. Crash recovery re-routes
//! a victim's in-flight requests through this same interface, so
//! requeues obey the configured policy too.

use crate::serving::workload::Request;

/// Request-assignment policy of the front-end router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through instances in order.
    RoundRobin,
    /// Fewest outstanding KV pages (held + queued demand).
    LeastOutstandingKv,
    /// Pin each session to one instance by hash.
    SessionAffinity,
    /// Expected prefix-hit pages net of load; session-affinity hash
    /// when nothing is cached. Requires the cluster's prefix store to
    /// fill `CandidateLoad::expected_prefix_hit_pages` — with no
    /// store the policy degenerates to `SessionAffinity`.
    CacheAware,
}

/// One routing candidate as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct CandidateLoad {
    /// Instance index in the cluster.
    pub instance: usize,
    /// KV pages held in the instance's pool plus pages its queued
    /// requests will need at admission.
    pub outstanding_kv_pages: usize,
    /// Prefix-cache pages of the request's shared prefix resident in
    /// this instance's HBM tier (zero when no prefix store is
    /// configured). Only `CacheAware` reads this.
    pub expected_prefix_hit_pages: usize,
}

/// Deterministic router: identical call sequences produce identical
/// assignments, so cluster runs replay bit-identically.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    rr: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, rr: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick an instance for `req` among `candidates` (non-empty),
    /// avoiding the `excluded` instances — the instances a retry is
    /// steering away from (slow degraded path, draining, or just
    /// crashed); an empty slice means no exclusions. The exclusions
    /// are dropped when they would empty the candidate set: a lone
    /// slow instance still beats rejecting the request.
    /// `SessionAffinity` re-hashes over the filtered set, failing the
    /// pinned session over exactly the way a consistent-hashing
    /// front-end rebalances on membership change.
    pub fn route(
        &mut self,
        req: &Request,
        candidates: &[CandidateLoad],
        excluded: &[usize],
    ) -> usize {
        assert!(!candidates.is_empty(), "router needs at least one candidate");
        if !excluded.is_empty() && candidates.len() > 1 {
            let filtered: Vec<CandidateLoad> = candidates
                .iter()
                .copied()
                .filter(|c| !excluded.contains(&c.instance))
                .collect();
            if !filtered.is_empty() {
                return self.pick(req, &filtered);
            }
        }
        self.pick(req, candidates)
    }

    fn pick(&mut self, req: &Request, candidates: &[CandidateLoad]) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let c = candidates[self.rr % candidates.len()].instance;
                self.rr += 1;
                c
            }
            RoutePolicy::LeastOutstandingKv => least_outstanding(candidates),
            RoutePolicy::SessionAffinity => session_pick(req, candidates),
            RoutePolicy::CacheAware => {
                let best = candidates
                    .iter()
                    .max_by_key(|c| {
                        let score = c.expected_prefix_hit_pages as i64
                            - c.outstanding_kv_pages as i64;
                        (
                            score,
                            std::cmp::Reverse((c.outstanding_kv_pages, c.instance)),
                        )
                    })
                    .expect("non-empty candidate set");
                if best.expected_prefix_hit_pages == 0 {
                    // nothing cached anywhere: stay sticky so the
                    // session's *next* turn has a home to hit
                    session_pick(req, candidates)
                } else {
                    best.instance
                }
            }
        }
    }
}

/// The candidate with the fewest outstanding KV pages, ties toward the
/// lowest instance index.
pub fn least_outstanding(candidates: &[CandidateLoad]) -> usize {
    candidates
        .iter()
        .min_by_key(|c| (c.outstanding_kv_pages, c.instance))
        .expect("non-empty candidate set")
        .instance
}

/// The session-affinity hash pick. Single-shot workloads set
/// `session = tenant`, so this is bit-compatible with the historical
/// tenant-affinity behaviour.
fn session_pick(req: &Request, candidates: &[CandidateLoad]) -> usize {
    let h = req
        .session
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(0x1234);
    candidates[(h % candidates.len() as u64) as usize].instance
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tenant: usize) -> Request {
        Request {
            id,
            tenant,
            session: tenant as u64,
            arrival: 0.0,
            prompt_tokens: 8,
            shared_prefix_tokens: 0,
            output_tokens: 4,
        }
    }

    fn cands(loads: &[usize]) -> Vec<CandidateLoad> {
        loads
            .iter()
            .enumerate()
            .map(|(instance, &outstanding_kv_pages)| CandidateLoad {
                instance,
                outstanding_kv_pages,
                expected_prefix_hit_pages: 0,
            })
            .collect()
    }

    fn cands_with_hits(loads: &[(usize, usize)]) -> Vec<CandidateLoad> {
        loads
            .iter()
            .enumerate()
            .map(
                |(instance, &(outstanding_kv_pages, expected_prefix_hit_pages))| CandidateLoad {
                    instance,
                    outstanding_kv_pages,
                    expected_prefix_hit_pages,
                },
            )
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let c = cands(&[100, 0, 50]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 0), &c, &[])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "load-oblivious cycle");
    }

    #[test]
    fn least_kv_picks_minimum_ties_to_lowest_index() {
        let mut r = Router::new(RoutePolicy::LeastOutstandingKv);
        assert_eq!(r.route(&req(0, 0), &cands(&[30, 10, 20]), &[]), 1);
        assert_eq!(r.route(&req(1, 0), &cands(&[10, 10, 20]), &[]), 0);
    }

    #[test]
    fn session_affinity_is_sticky_and_spreads_tenants() {
        let mut r = Router::new(RoutePolicy::SessionAffinity);
        let c = cands(&[0, 0, 0, 0]);
        for tenant in 0..16 {
            let first = r.route(&req(0, tenant), &c, &[]);
            for id in 1..8 {
                assert_eq!(
                    r.route(&req(id, tenant), &c, &[]),
                    first,
                    "tenant {tenant} must stay pinned"
                );
            }
        }
        let assigned: std::collections::BTreeSet<usize> = (0..64)
            .map(|tenant| r.route(&req(0, tenant), &c, &[]))
            .collect();
        assert!(assigned.len() > 1, "many tenants must spread out");
    }

    #[test]
    fn routing_ignores_load_only_for_oblivious_policies() {
        // least-kv reacts to a load change, round-robin does not
        let mut lk = Router::new(RoutePolicy::LeastOutstandingKv);
        assert_eq!(lk.route(&req(0, 0), &cands(&[5, 9]), &[]), 0);
        assert_eq!(lk.route(&req(1, 0), &cands(&[12, 9]), &[]), 1);
    }

    #[test]
    fn retry_reroute_skips_the_excluded_instance() {
        // regression (ISSUE 6): a retried request must not land back
        // on the instance it is retrying away from — even when that
        // instance still looks best by load — unless it is the only
        // candidate left
        let mut r = Router::new(RoutePolicy::LeastOutstandingKv);
        let c = cands(&[0, 10, 20]);
        assert_eq!(r.route(&req(0, 0), &c, &[]), 0, "0 wins on load");
        assert_eq!(r.route(&req(0, 0), &c, &[0]), 1);
        // a sole candidate is never excluded: slow beats rejected
        let only = cands(&[50]);
        assert_eq!(r.route(&req(0, 0), &only, &[0]), 0);
        // excluding everything degenerates to no exclusion
        assert_eq!(r.route(&req(0, 0), &c, &[0, 1, 2]), 0);
    }

    #[test]
    fn session_affinity_fails_over_from_an_excluded_instance() {
        let mut r = Router::new(RoutePolicy::SessionAffinity);
        let c = cands(&[0, 0, 0, 0]);
        for tenant in 0..16 {
            let pinned = r.route(&req(0, tenant), &c, &[]);
            let rerouted = r.route(&req(0, tenant), &c, &[pinned]);
            assert_ne!(
                rerouted, pinned,
                "tenant {tenant} must fail over off its pinned instance"
            );
            // and the fail-over itself is deterministic
            assert_eq!(r.route(&req(0, tenant), &c, &[pinned]), rerouted);
        }
    }

    #[test]
    fn round_robin_exclusion_cycles_over_the_filtered_set() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let c = cands(&[0, 0, 0]);
        let picks: Vec<usize> = (0..4).map(|i| r.route(&req(i, 0), &c, &[1])).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "instance 1 never picked");
    }

    #[test]
    fn cache_aware_follows_the_prefix_unless_swamped() {
        let mut r = Router::new(RoutePolicy::CacheAware);
        // instance 2 holds 20 cached pages and modest load: it wins
        let c = cands_with_hits(&[(0, 0), (5, 0), (8, 20)]);
        assert_eq!(r.route(&req(0, 3), &c, &[]), 2);
        // same hit, but instance 2 is now swamped: the idle instance's
        // net score wins (0 - 0 > 20 - 40)
        let swamped = cands_with_hits(&[(0, 0), (5, 0), (40, 20)]);
        assert_eq!(r.route(&req(0, 3), &swamped, &[]), 0);
    }

    #[test]
    fn cache_aware_cold_sessions_fall_back_to_session_affinity() {
        let mut aware = Router::new(RoutePolicy::CacheAware);
        let mut affinity = Router::new(RoutePolicy::SessionAffinity);
        let c = cands(&[3, 1, 4, 1]);
        for tenant in 0..16 {
            assert_eq!(
                aware.route(&req(0, tenant), &c, &[]),
                affinity.route(&req(0, tenant), &c, &[]),
                "no cached prefix anywhere: stay sticky, not least-loaded"
            );
        }
    }

    #[test]
    fn cache_aware_fails_over_under_exclusion() {
        let mut r = Router::new(RoutePolicy::CacheAware);
        let c = cands_with_hits(&[(0, 0), (2, 9)]);
        assert_eq!(r.route(&req(0, 0), &c, &[]), 1, "follow the cache");
        assert_eq!(r.route(&req(0, 0), &c, &[1]), 0, "excluded: fail over");
    }
}
