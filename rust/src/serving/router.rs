//! Front-end request router for multi-instance serving.
//!
//! The cluster simulator places N batcher instances on
//! `supernode::Topology` devices; arrivals enter through a router that
//! assigns each request to an instance under a pluggable policy:
//!
//! - **RoundRobin** — stateless baseline, ignores load entirely;
//! - **LeastOutstandingKv** — the KV-aware policy: pick the instance
//!   with the fewest outstanding KV pages (pages held in its
//!   `PagePool` plus pages the queued requests will need). Serving
//!   load is KV-page pressure, not request count, so this beats
//!   least-requests when prompt lengths are heavy-tailed;
//! - **SessionAffinity** — hash the session (tenant) to a fixed
//!   instance, the prefix-cache-friendly policy: all turns of one
//!   session land where its KV prefix already lives. Only sensible
//!   for many-tenant workloads — a single hot session saturates its
//!   pinned instance by design.
//!
//! The same `Router` is reused for decode-target selection in
//! disaggregated mode (there the policy is always
//! least-outstanding-KV: the KV pages are about to move to that
//! instance, so page headroom is the only signal that matters).
//!
//! Under elasticity the candidate set is *dynamic*: the cluster passes
//! only instances currently in the Serving state, so warming-up,
//! draining, released, and crashed instances never receive work. The
//! router is deliberately stateless about membership — `RoundRobin`
//! cycles over whatever set it is handed (its counter survives set
//! changes), and `SessionAffinity` hashes into the current set, which
//! means a scale event re-pins sessions the way consistent-hashing
//! front-ends rebalance on membership change. Crash recovery re-routes
//! a victim's in-flight requests through this same interface, so
//! requeues obey the configured policy too.

use crate::serving::workload::Request;

/// Request-assignment policy of the front-end router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through instances in order.
    RoundRobin,
    /// Fewest outstanding KV pages (held + queued demand).
    LeastOutstandingKv,
    /// Pin each session (tenant) to one instance by hash.
    SessionAffinity,
}

/// One routing candidate as the router sees it.
#[derive(Debug, Clone, Copy)]
pub struct CandidateLoad {
    /// Instance index in the cluster.
    pub instance: usize,
    /// KV pages held in the instance's pool plus pages its queued
    /// requests will need at admission.
    pub outstanding_kv_pages: usize,
}

/// Deterministic router: identical call sequences produce identical
/// assignments, so cluster runs replay bit-identically.
#[derive(Debug, Clone)]
pub struct Router {
    policy: RoutePolicy,
    rr: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, rr: 0 }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick an instance for `req` among `candidates`, avoiding
    /// `exclude` — the instance a retry is steering away from (slow
    /// degraded path, draining, or just crashed). The exclusion is
    /// dropped when it would empty the candidate set: a lone slow
    /// instance still beats rejecting the request. `SessionAffinity`
    /// re-hashes over the filtered set, failing the pinned session
    /// over exactly the way a consistent-hashing front-end rebalances
    /// on membership change.
    pub fn route_excluding(
        &mut self,
        req: &Request,
        candidates: &[CandidateLoad],
        exclude: Option<usize>,
    ) -> usize {
        if let Some(x) = exclude {
            if candidates.len() > 1 {
                let filtered: Vec<CandidateLoad> = candidates
                    .iter()
                    .copied()
                    .filter(|c| c.instance != x)
                    .collect();
                if !filtered.is_empty() {
                    return self.route(req, &filtered);
                }
            }
        }
        self.route(req, candidates)
    }

    /// Pick an instance for `req` among `candidates` (non-empty).
    pub fn route(&mut self, req: &Request, candidates: &[CandidateLoad]) -> usize {
        assert!(!candidates.is_empty(), "router needs at least one candidate");
        match self.policy {
            RoutePolicy::RoundRobin => {
                let c = candidates[self.rr % candidates.len()].instance;
                self.rr += 1;
                c
            }
            RoutePolicy::LeastOutstandingKv => least_outstanding(candidates),
            RoutePolicy::SessionAffinity => {
                let h = (req.tenant as u64)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(0x1234);
                candidates[(h % candidates.len() as u64) as usize].instance
            }
        }
    }
}

/// The candidate with the fewest outstanding KV pages, ties toward the
/// lowest instance index.
pub fn least_outstanding(candidates: &[CandidateLoad]) -> usize {
    candidates
        .iter()
        .min_by_key(|c| (c.outstanding_kv_pages, c.instance))
        .expect("non-empty candidate set")
        .instance
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tenant: usize) -> Request {
        Request {
            id,
            tenant,
            arrival: 0.0,
            prompt_tokens: 8,
            output_tokens: 4,
        }
    }

    fn cands(loads: &[usize]) -> Vec<CandidateLoad> {
        loads
            .iter()
            .enumerate()
            .map(|(instance, &outstanding_kv_pages)| CandidateLoad {
                instance,
                outstanding_kv_pages,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let c = cands(&[100, 0, 50]);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 0), &c)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2], "load-oblivious cycle");
    }

    #[test]
    fn least_kv_picks_minimum_ties_to_lowest_index() {
        let mut r = Router::new(RoutePolicy::LeastOutstandingKv);
        assert_eq!(r.route(&req(0, 0), &cands(&[30, 10, 20])), 1);
        assert_eq!(r.route(&req(1, 0), &cands(&[10, 10, 20])), 0);
    }

    #[test]
    fn session_affinity_is_sticky_and_spreads_tenants() {
        let mut r = Router::new(RoutePolicy::SessionAffinity);
        let c = cands(&[0, 0, 0, 0]);
        for tenant in 0..16 {
            let first = r.route(&req(0, tenant), &c);
            for id in 1..8 {
                assert_eq!(
                    r.route(&req(id, tenant), &c),
                    first,
                    "tenant {tenant} must stay pinned"
                );
            }
        }
        let assigned: std::collections::BTreeSet<usize> =
            (0..64).map(|tenant| r.route(&req(0, tenant), &c)).collect();
        assert!(assigned.len() > 1, "many tenants must spread out");
    }

    #[test]
    fn routing_ignores_load_only_for_oblivious_policies() {
        // least-kv reacts to a load change, round-robin does not
        let mut lk = Router::new(RoutePolicy::LeastOutstandingKv);
        assert_eq!(lk.route(&req(0, 0), &cands(&[5, 9])), 0);
        assert_eq!(lk.route(&req(1, 0), &cands(&[12, 9])), 1);
    }

    #[test]
    fn retry_reroute_skips_the_excluded_instance() {
        // regression (ISSUE 6): a retried request must not land back
        // on the instance it is retrying away from — even when that
        // instance still looks best by load — unless it is the only
        // candidate left
        let mut r = Router::new(RoutePolicy::LeastOutstandingKv);
        let c = cands(&[0, 10, 20]);
        assert_eq!(r.route(&req(0, 0), &c), 0, "0 wins on load");
        assert_eq!(r.route_excluding(&req(0, 0), &c, Some(0)), 1);
        // a sole candidate is never excluded: slow beats rejected
        let only = cands(&[50]);
        assert_eq!(r.route_excluding(&req(0, 0), &only, Some(0)), 0);
        // no exclusion behaves exactly like route()
        assert_eq!(r.route_excluding(&req(0, 0), &c, None), 0);
    }

    #[test]
    fn session_affinity_fails_over_from_an_excluded_instance() {
        let mut r = Router::new(RoutePolicy::SessionAffinity);
        let c = cands(&[0, 0, 0, 0]);
        for tenant in 0..16 {
            let pinned = r.route(&req(0, tenant), &c);
            let rerouted = r.route_excluding(&req(0, tenant), &c, Some(pinned));
            assert_ne!(
                rerouted, pinned,
                "tenant {tenant} must fail over off its pinned instance"
            );
            // and the fail-over itself is deterministic
            assert_eq!(
                r.route_excluding(&req(0, tenant), &c, Some(pinned)),
                rerouted
            );
        }
    }

    #[test]
    fn round_robin_exclusion_cycles_over_the_filtered_set() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let c = cands(&[0, 0, 0]);
        let picks: Vec<usize> = (0..4)
            .map(|i| r.route_excluding(&req(i, 0), &c, Some(1)))
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "instance 1 never picked");
    }
}
