//! The simulated continuous batcher: the same admission/refill policy
//! core the real runtime path uses, driven in virtual time over a
//! fleet of replicas.
//!
//! ## Shared policy core
//!
//! [`plan_refill`] is the slot-refill logic factored out of
//! `coordinator::server::InferenceServer`: walk slots in index order,
//! admit the FIFO head into each empty slot, clamp prompts to the
//! sequence budget. The real batcher calls it with an always-true
//! gate (PJRT executes the numerics, the host has no KV budget); the
//! simulator plugs a KV-page gate into the *same* code, so admission
//! behaviour cannot drift between the measured path and the deployed
//! path.
//!
//! ## Event model
//!
//! Entities are replicas (one device group each); events are request
//! arrivals and iteration completions. Each iteration advances every
//! active sequence by one token (continuous batching), with newly
//! admitted sequences paying their prefill inside the iteration that
//! admits them. Iteration latency comes from `KvCacheConfig` bandwidth
//! math (see [`CostModel`]); KV pages are tracked per sequence by
//! `serving::memory`, with HyperOffload-style demotion to the DRAM
//! pool or recompute-style preemption under pressure. Busy intervals
//! are recorded per replica through a [`TraceCollector`], so every
//! metric of the DES substrate (utilization, overlap, windowed busy)
//! applies to serving traces — and under [`TraceMode::Streaming`] the
//! interval log is never materialized, which is what lets city-scale
//! fleets (1000+ replicas, 10^7+ iteration events) fit in memory.

use crate::hyperoffload::kvcache::KvCacheConfig;
use crate::serving::memory::{MemoryPolicy, ServingMemory};
use crate::serving::metrics::{RequestOutcome, ServingReport};
use crate::serving::workload::Request;
use crate::sim::{tags, ResourceId, TraceCollector, TraceMode};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// One admission decision from [`plan_refill`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    /// Slot to fill.
    pub slot: usize,
    /// Index into the FIFO queue snapshot. Admissions always consume
    /// the queue in order: the k-th admission has `queue_index == k`.
    pub queue_index: usize,
    /// Prompt length after clamping to `max_seq - 1`.
    pub prompt_len: usize,
}

/// Admission/refill policy core shared by the real continuous batcher
/// (`coordinator::server::InferenceServer`) and the simulated one
/// ([`simulate`]).
///
/// Walks slots in index order and plans to admit the FIFO head into
/// each empty slot while `gate(queue_index, clamped_prompt)` accepts,
/// clamping prompts to `max_seq - 1` so one decode position always
/// remains. A rejected head blocks the queue — continuous batching
/// preserves arrival order, so there is no reordering around a
/// request that does not fit yet.
pub fn plan_refill(
    occupied: &[bool],
    max_seq: usize,
    queued_prompt_lens: &[usize],
    mut gate: impl FnMut(usize, usize) -> bool,
) -> Vec<Admission> {
    assert!(max_seq >= 1, "max_seq must be at least 1");
    let mut plan = Vec::new();
    let mut qi = 0usize;
    for (slot, occ) in occupied.iter().enumerate() {
        if *occ {
            continue;
        }
        if qi >= queued_prompt_lens.len() {
            break;
        }
        let prompt_len = queued_prompt_lens[qi].min(max_seq - 1);
        if !gate(qi, prompt_len) {
            break;
        }
        plan.push(Admission {
            slot,
            queue_index: qi,
            prompt_len,
        });
        qi += 1;
    }
    plan
}

/// Iteration cost model, derived from `KvCacheConfig` bandwidth math.
///
/// A decode iteration runs two overlapped pipelines (HyperOffload
/// §3.2): the **HBM side** reads the resident weight fraction plus all
/// HBM-held KV and runs attention/prefill compute; the **pool side**
/// streams the offloaded weight fraction plus any pool-resident KV
/// pages over the UB fabric. The iteration takes the maximum of the
/// two, plus a fixed scheduling overhead — the same max-of-pipelines
/// shape as `KvCacheConfig::decode_latency`, generalized to a batch
/// with split-tier KV and in-flight prefill.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub kv: KvCacheConfig,
    /// Fraction of the weights streamed from the DRAM pool each
    /// iteration (frees HBM for KV pages, adds pool-side traffic).
    pub offload_frac: f64,
    /// Prefill compute throughput, prompt tokens/second.
    pub prefill_tokens_per_s: f64,
    /// Fixed scheduling overhead per batcher iteration, seconds.
    pub iteration_overhead: f64,
}

impl CostModel {
    pub fn new(kv: KvCacheConfig, offload_frac: f64) -> Self {
        assert!(
            (0.0..=1.0).contains(&offload_frac),
            "offload_frac must be in [0, 1]"
        );
        Self {
            kv,
            offload_frac,
            prefill_tokens_per_s: 100e3,
            iteration_overhead: 100e-6,
        }
    }

    /// Latency of one iteration over a batch holding `hbm_ctx_tokens`
    /// KV entries in HBM and `pool_ctx_tokens` in the DRAM pool, with
    /// `prefill_tokens` of newly admitted prompt work. A pool pipeline
    /// with nothing to stream costs exactly zero — the degenerate
    /// `offload_frac == 0` configuration stays finite even when
    /// `pool_bw` is irrelevant and left at zero.
    pub fn iteration_latency(
        &self,
        hbm_ctx_tokens: usize,
        pool_ctx_tokens: usize,
        prefill_tokens: usize,
    ) -> f64 {
        let w = self.kv.weight_bytes as f64;
        let kvb = self.kv.kv_bytes_per_token as f64;
        let hbm_side = ((1.0 - self.offload_frac) * w + hbm_ctx_tokens as f64 * kvb)
            / self.kv.hbm_bw
            + (hbm_ctx_tokens + pool_ctx_tokens) as f64 / self.kv.attn_tokens_per_s
            + prefill_tokens as f64 / self.prefill_tokens_per_s;
        let pool_bytes = self.offload_frac * w + pool_ctx_tokens as f64 * kvb;
        let pool_side = if pool_bytes == 0.0 {
            0.0
        } else {
            pool_bytes / self.kv.pool_bw
        };
        self.iteration_overhead + hbm_side.max(pool_side)
    }
}

/// Configuration of a simulated serving deployment.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Independent replicas (device groups); arrivals are routed to
    /// the least-loaded one.
    pub fleet: usize,
    /// Concurrent sequences per replica (the batcher's slot count).
    pub slots: usize,
    /// Max tokens per sequence, prompt + output (the artifact's `seq`).
    pub max_seq: usize,
    pub cost: CostModel,
    pub policy: MemoryPolicy,
    /// DRAM-pool page capacity per replica (ignored under `NoOffload`).
    pub pool_pages: usize,
    /// Preemptions a request survives before being dropped as rejected.
    pub max_preemptions: u32,
    /// Trace representation: [`TraceMode::Indexed`] keeps the full
    /// CSR-indexed interval log (the default; what tests assert on),
    /// [`TraceMode::Streaming`] folds intervals into accumulators as
    /// they complete — city-scale fleets run in O(fleet) trace memory.
    pub trace_mode: TraceMode,
}

#[derive(Debug, Clone)]
struct QueuedReq {
    req: Request,
    preemptions: u32,
    /// Preserved across recompute-preemption: the client already saw
    /// its first token.
    first_token: Option<f64>,
}

#[derive(Debug, Clone)]
struct ActiveSeq {
    req: Request,
    /// Prompt length after clamping to the sequence budget.
    prompt_len: usize,
    produced: usize,
    admitted_at: f64,
    first_token: Option<f64>,
    preemptions: u32,
}

impl ActiveSeq {
    /// KV entries resident for this sequence.
    fn ctx(&self) -> usize {
        self.prompt_len + self.produced
    }

    fn target(&self, max_seq: usize) -> usize {
        self.req.output_tokens.min(max_seq - self.prompt_len)
    }
}

#[derive(Debug, Default)]
struct Stats {
    outcomes: Vec<RequestOutcome>,
    rejected: u64,
    preemptions: u64,
    decoded_tokens: u64,
    prefill_tokens: u64,
    trace: TraceCollector,
    makespan: f64,
}

#[derive(Debug)]
struct Replica {
    mem: ServingMemory,
    queue: VecDeque<QueuedReq>,
    active: Vec<Option<ActiveSeq>>,
    /// Completion time of the in-flight iteration, if any.
    iter_end: Option<f64>,
    /// Σ ctx tokens of active sequences at the current iteration's
    /// start (for the cluster-wide admitted-context watermark).
    cur_ctx_tokens: usize,
}

impl Replica {
    fn new(cfg: &ServingConfig) -> Self {
        Self {
            mem: ServingMemory::new(
                &cfg.cost.kv,
                cfg.cost.offload_frac,
                cfg.policy,
                cfg.pool_pages,
            ),
            queue: VecDeque::new(),
            active: (0..cfg.slots).map(|_| None).collect(),
            iter_end: None,
            cur_ctx_tokens: 0,
        }
    }

    fn active_count(&self) -> usize {
        self.active.iter().filter(|s| s.is_some()).count()
    }

    /// Routing load: sequences in flight plus queued.
    fn load(&self) -> usize {
        self.active_count() + self.queue.len()
    }

    /// Active sequence ids, coldest first (earliest admitted — their
    /// head pages are the coldest, matching `PagedKvCache`'s
    /// oldest-page demotion).
    fn cold_order(&self) -> Vec<u64> {
        let mut v: Vec<(f64, u64)> = self
            .active
            .iter()
            .flatten()
            .map(|s| (s.admitted_at, s.req.id))
            .collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        v.into_iter().map(|(_, id)| id).collect()
    }

    /// Victim for recompute-preemption: the youngest admission (least
    /// wasted work), ties broken toward the higher slot.
    fn youngest_slot(&self) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for (i, s) in self.active.iter().enumerate() {
            if let Some(seq) = s {
                let better = match best {
                    None => true,
                    Some(b) => seq.admitted_at > b.0 || (seq.admitted_at == b.0 && i > b.1),
                };
                if better {
                    best = Some((seq.admitted_at, i));
                }
            }
        }
        best.map(|b| b.1)
    }

    /// Evict one sequence, recompute-style: its pages are released and
    /// it restarts (re-prefills) from the queue head — unless it has
    /// exhausted its preemption budget, in which case it is rejected.
    fn preempt(&mut self, slot: usize, max_preemptions: u32, stats: &mut Stats) {
        let seq = self.active[slot].take().expect("preempting an empty slot");
        self.mem.pool.release(seq.req.id);
        stats.preemptions += 1;
        let preemptions = seq.preemptions + 1;
        if preemptions > max_preemptions {
            stats.rejected += 1;
            return;
        }
        self.queue.push_front(QueuedReq {
            req: seq.req,
            preemptions,
            first_token: seq.first_token,
        });
    }

    /// Grow continuing sequences by the pages this iteration needs,
    /// demoting cold pages under the pool policy and preempting the
    /// youngest sequence when no page can be found anywhere.
    fn grow_active(&mut self, cfg: &ServingConfig, stats: &mut Stats) {
        let mut i = 0usize;
        while i < self.active.len() {
            let (id, need) = match &self.active[i] {
                Some(s) => (s.req.id, self.mem.pages_for(s.ctx())),
                None => {
                    i += 1;
                    continue;
                }
            };
            let have = self.mem.pool.seq_pages(id).total();
            if need <= have {
                i += 1;
                continue;
            }
            let delta = need - have;
            let cold = self.cold_order();
            if self.mem.ensure_hbm_free(delta, &cold) && self.mem.pool.try_alloc_hbm(id, delta)
            {
                i += 1;
                continue;
            }
            let victim = self
                .youngest_slot()
                .expect("growth requires at least one active sequence");
            self.preempt(victim, cfg.max_preemptions, stats);
            // victim == i: the growing sequence itself was evicted and
            // the slot is empty now; otherwise retry the same slot
            // against the freed pages.
        }
    }

    /// An iteration completed at `t`: every active sequence produced
    /// one token; retire the finished ones.
    fn finish_iteration(&mut self, t: f64, cfg: &ServingConfig, stats: &mut Stats) {
        debug_assert!(self.iter_end.is_some(), "finish without an iteration");
        self.iter_end = None;
        for slot in self.active.iter_mut() {
            let Some(seq) = slot else { continue };
            seq.produced += 1;
            stats.decoded_tokens += 1;
            if seq.first_token.is_none() {
                seq.first_token = Some(t);
            }
            if seq.produced >= seq.target(cfg.max_seq) || seq.ctx() >= cfg.max_seq {
                stats.outcomes.push(RequestOutcome {
                    id: seq.req.id,
                    tenant: seq.req.tenant,
                    arrival: seq.req.arrival,
                    first_token: seq.first_token.unwrap_or(t),
                    finish: t,
                    prompt_tokens: seq.prompt_len,
                    output_tokens: seq.produced,
                    preemptions: seq.preemptions,
                });
                self.mem.pool.release(seq.req.id);
                *slot = None;
            }
        }
    }

    /// Refill slots through the shared policy core and schedule the
    /// next iteration (if any sequence is active).
    fn start_iteration(&mut self, ridx: usize, t: f64, cfg: &ServingConfig, stats: &mut Stats) {
        debug_assert!(self.iter_end.is_none(), "iteration already in flight");
        self.grow_active(cfg, stats);
        let mut total_prefill = 0usize;
        loop {
            let occupied: Vec<bool> = self.active.iter().map(Option::is_some).collect();
            // the plan can admit at most one request per empty slot, so
            // only that prefix of the queue is ever consulted — keeps
            // refill O(slots) even with a deep backlog
            let empty = occupied.iter().filter(|o| !**o).count();
            let lens: Vec<usize> =
                self.queue.iter().take(empty).map(|q| q.req.prompt_tokens).collect();
            let qids: Vec<u64> = self.queue.iter().take(empty).map(|q| q.req.id).collect();
            let cold = self.cold_order();
            let mem = &mut self.mem;
            let plan = plan_refill(&occupied, cfg.max_seq, &lens, |qi, prompt_len| {
                let pages = mem.pages_for(prompt_len);
                // a prompt larger than the whole HBM budget can never
                // fit — refuse before demoting anything, or an
                // unadmittable head would migrate every in-flight
                // sequence's pages to the slow pool for nothing
                pages <= mem.pool.hbm_capacity()
                    && mem.ensure_hbm_free(pages, &cold)
                    && mem.pool.try_alloc_hbm(qids[qi], pages)
            });
            for adm in &plan {
                let q = self.queue.pop_front().expect("refill plan exceeds queue");
                total_prefill += adm.prompt_len;
                self.active[adm.slot] = Some(ActiveSeq {
                    req: q.req,
                    prompt_len: adm.prompt_len,
                    produced: 0,
                    admitted_at: t,
                    first_token: q.first_token,
                    preemptions: q.preemptions,
                });
            }
            if !plan.is_empty() || self.active_count() > 0 {
                break;
            }
            // Empty replica, nothing admitted: the head needs more
            // pages than the whole HBM budget — it can never fit.
            match self.queue.pop_front() {
                Some(_) => stats.rejected += 1,
                None => break,
            }
        }

        // Cost the iteration from the tiered KV footprint.
        let tpp = self.mem.tokens_per_page();
        let mut hbm_tokens = 0usize;
        let mut pool_tokens = 0usize;
        for seq in self.active.iter().flatten() {
            let ctx = seq.ctx();
            let in_pool = (self.mem.pool.seq_pages(seq.req.id).pool * tpp).min(ctx);
            pool_tokens += in_pool;
            hbm_tokens += ctx - in_pool;
        }
        self.cur_ctx_tokens = hbm_tokens + pool_tokens;
        if self.active_count() == 0 {
            // Idle: the next routed arrival kicks the replica.
            return;
        }
        stats.prefill_tokens += total_prefill as u64;
        let finish = t + cfg
            .cost
            .iteration_latency(hbm_tokens, pool_tokens, total_prefill);
        stats.trace.push(
            ResourceId(ridx),
            t,
            finish,
            if total_prefill > 0 {
                tags::PREFILL
            } else {
                tags::DECODE
            },
        );
        stats.makespan = stats.makespan.max(finish);
        self.iter_end = Some(finish);
    }
}

/// Run the serving simulation to completion: every request is either
/// completed or rejected when this returns. Deterministic: identical
/// inputs produce a bit-identical report.
pub fn simulate(cfg: &ServingConfig, requests: &[Request]) -> ServingReport {
    assert!(cfg.fleet >= 1, "fleet must be non-empty");
    assert!(cfg.slots >= 1, "need at least one slot");
    assert!(cfg.max_seq >= 2, "need room for a prompt and one decode position");
    debug_assert!(
        requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
        "requests must be sorted by arrival time"
    );

    let mut replicas: Vec<Replica> = (0..cfg.fleet).map(|_| Replica::new(cfg)).collect();
    let mut stats = Stats {
        trace: TraceCollector::new(cfg.trace_mode),
        ..Default::default()
    };
    let mut peak_context = 0usize;
    let mut next_arrival = 0usize;
    // Pending iteration-end events keyed by (finish bits, replica):
    // non-negative doubles order as their bit patterns, so the heap
    // pops the same (time, lowest index) the old O(fleet) min-scan
    // chose — but in O(log fleet), which is what makes 1000+-replica
    // city-scale fleets tractable. A replica has at most one iteration
    // in flight, so every entry is current (no lazy deletion needed).
    let mut iter_heap: BinaryHeap<Reverse<(u64, usize)>> =
        BinaryHeap::with_capacity(cfg.fleet.min(1 << 16));
    // Σ cur_ctx_tokens across the fleet, maintained incrementally —
    // the admitted-context watermark without an O(fleet) sum per event.
    let mut total_ctx = 0usize;

    // start (or try to start) an iteration on replica `i` at `t`,
    // keeping the event heap and the running context sum in step
    macro_rules! kick_replica {
        ($i:expr, $t:expr) => {{
            let i = $i;
            let before = replicas[i].cur_ctx_tokens;
            replicas[i].start_iteration(i, $t, cfg, &mut stats);
            total_ctx = total_ctx - before + replicas[i].cur_ctx_tokens;
            if let Some(f) = replicas[i].iter_end {
                iter_heap.push(Reverse((f.to_bits(), i)));
            }
        }};
    }

    loop {
        let ta = requests.get(next_arrival).map(|r| r.arrival);
        let te = iter_heap.peek().map(|&Reverse((bits, i))| (f64::from_bits(bits), i));
        let arrival_first = match (ta, te) {
            (None, None) => break,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            // ties: enqueue the arrival first so the ending
            // iteration's refill can admit it
            (Some(t), Some((e, _))) => t <= e,
        };
        if arrival_first {
            let req = requests[next_arrival];
            next_arrival += 1;
            let target = replicas
                .iter()
                .enumerate()
                .min_by_key(|(i, rep)| (rep.load(), *i))
                .map(|(i, _)| i)
                .expect("fleet is non-empty");
            replicas[target].queue.push_back(QueuedReq {
                req,
                preemptions: 0,
                first_token: None,
            });
            if replicas[target].iter_end.is_none() {
                kick_replica!(target, req.arrival);
            }
        } else {
            iter_heap.pop();
            let (t, i) = te.expect("iteration end exists");
            replicas[i].finish_iteration(t, cfg, &mut stats);
            kick_replica!(i, t);
        }
        peak_context = peak_context.max(total_ctx);
    }

    let demotions = replicas.iter().map(|r| r.mem.pool.demotions).sum();
    let Stats {
        outcomes,
        rejected,
        preemptions,
        decoded_tokens,
        prefill_tokens,
        trace,
        makespan,
    } = stats;
    ServingReport {
        outcomes,
        rejected,
        preemptions,
        demotions,
        decoded_tokens,
        prefill_tokens,
        peak_context_tokens: peak_context,
        makespan,
        trace: trace.finish(makespan, cfg.fleet),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- plan_refill (the shared policy core) ------------------------

    #[test]
    fn refill_fills_empty_slots_fifo() {
        let plan = plan_refill(&[false, true, false, false], 16, &[3, 5, 7, 9], |_, _| true);
        assert_eq!(plan.len(), 3);
        assert_eq!((plan[0].slot, plan[0].queue_index, plan[0].prompt_len), (0, 0, 3));
        assert_eq!((plan[1].slot, plan[1].queue_index, plan[1].prompt_len), (2, 1, 5));
        assert_eq!((plan[2].slot, plan[2].queue_index, plan[2].prompt_len), (3, 2, 7));
    }

    #[test]
    fn refill_clamps_prompts_to_seq_budget() {
        let plan = plan_refill(&[false], 8, &[100], |_, _| true);
        assert_eq!(plan[0].prompt_len, 7);
    }

    #[test]
    fn refill_gate_blocks_head_and_everything_behind() {
        let plan = plan_refill(&[false, false, false], 16, &[4, 1, 1], |qi, _| qi != 0);
        assert!(plan.is_empty(), "blocked head must not be overtaken");
    }

    #[test]
    fn refill_stops_when_queue_empty() {
        let plan = plan_refill(&[false, false], 16, &[9], |_, _| true);
        assert_eq!(plan.len(), 1);
    }

    // ---- the simulator ----------------------------------------------

    fn tiny_kv(pages_at_f0: u64) -> KvCacheConfig {
        KvCacheConfig {
            kv_bytes_per_token: 1024,
            tokens_per_page: 16,
            weight_bytes: 1 << 20,
            hbm_usable: (1 << 20) + pages_at_f0 * 16 * 1024,
            hbm_bw: 1e12,
            pool_bw: 100e9,
            attn_tokens_per_s: 40e6,
        }
    }

    fn fixed_requests(n: u64, prompt: usize, output: usize, spacing: f64) -> Vec<Request> {
        (0..n)
            .map(|id| Request {
                id,
                tenant: 0,
                session: 0,
                arrival: id as f64 * spacing,
                prompt_tokens: prompt,
                shared_prefix_tokens: 0,
                output_tokens: output,
            })
            .collect()
    }

    fn cfg(kv: KvCacheConfig, frac: f64, policy: MemoryPolicy, slots: usize) -> ServingConfig {
        ServingConfig {
            fleet: 1,
            slots,
            max_seq: 512,
            cost: CostModel::new(kv, frac),
            policy,
            pool_pages: 64,
            max_preemptions: 4,
            trace_mode: TraceMode::Indexed,
        }
    }

    #[test]
    fn unloaded_fleet_completes_everything() {
        let c = cfg(tiny_kv(64), 0.0, MemoryPolicy::NoOffload, 4);
        let reqs = fixed_requests(8, 32, 8, 0.05);
        let rep = simulate(&c, &reqs);
        assert_eq!(rep.outcomes.len(), 8);
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.preemptions, 0);
        assert_eq!(rep.decoded_tokens, 8 * 8);
        assert!(rep.makespan > 0.0);
        assert_eq!(rep.trace.resources(), 1);
        for o in &rep.outcomes {
            assert!(o.first_token > o.arrival);
            assert!(o.finish >= o.first_token);
            assert_eq!(o.output_tokens, 8);
        }
    }

    #[test]
    fn deterministic_bit_identical_reruns() {
        // tight arrivals: the preemption path is exercised and must
        // replay bit-identically too
        let c = cfg(tiny_kv(16), 0.0, MemoryPolicy::NoOffload, 6);
        let reqs = fixed_requests(40, 48, 12, 1e-5);
        let a = simulate(&c, &reqs);
        let b = simulate(&c, &reqs);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        assert_eq!(a.preemptions, b.preemptions);
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }

    #[test]
    fn pressure_preempts_under_no_offload() {
        // 16 pages = 256 tokens; 6 slots x (48 + 12) tokens won't fit,
        // and near-simultaneous arrivals keep every slot contended
        let c = cfg(tiny_kv(16), 0.0, MemoryPolicy::NoOffload, 6);
        let reqs = fixed_requests(30, 48, 12, 1e-5);
        let rep = simulate(&c, &reqs);
        assert!(rep.preemptions > 0, "expected page-pressure preemptions");
        assert_eq!(rep.demotions, 0, "no pool under NoOffload");
        assert_eq!(rep.outcomes.len() as u64 + rep.rejected, 30);
    }

    #[test]
    fn pool_offload_demotes_instead_of_thrashing() {
        let c = cfg(tiny_kv(16), 0.1, MemoryPolicy::PoolOffload, 6);
        let reqs = fixed_requests(30, 48, 12, 1e-5);
        let rep = simulate(&c, &reqs);
        assert!(rep.demotions > 0, "expected HBM->pool demotions");
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.outcomes.len(), 30);
        let no = simulate(&cfg(tiny_kv(16), 0.0, MemoryPolicy::NoOffload, 6), &reqs);
        assert!(
            rep.outcomes.len() >= no.outcomes.len(),
            "offload must not complete fewer requests"
        );
    }

    #[test]
    fn degenerate_cost_model_endpoints_stay_finite() {
        let kv = tiny_kv(16);
        for frac in [0.0, 1.0] {
            let cm = CostModel::new(kv.clone(), frac);
            for (h, p, f) in [(0, 0, 0), (100, 0, 32), (0, 50, 0), (64, 64, 64)] {
                let lat = cm.iteration_latency(h, p, f);
                assert!(lat.is_finite() && lat > 0.0, "frac={frac} lat={lat}");
            }
        }
        // pool_bw = 0 with no pool traffic: finite, not 0/0 = NaN
        let mut kv0 = tiny_kv(16);
        kv0.pool_bw = 0.0;
        let cm = CostModel::new(kv0, 0.0);
        assert!(cm.iteration_latency(64, 0, 8).is_finite());
    }

    #[test]
    fn zero_capacity_config_rejects_everything_and_terminates() {
        // weights alone overflow the usable HBM: kv_token_capacity is
        // 0, the page pool is empty, and every prompt is rejected up
        // front — the admission loop must not spin
        let kv = KvCacheConfig {
            kv_bytes_per_token: 1024,
            tokens_per_page: 16,
            weight_bytes: 1 << 22,
            hbm_usable: 1 << 20,
            hbm_bw: 1e12,
            pool_bw: 100e9,
            attn_tokens_per_s: 40e6,
        };
        assert_eq!(kv.kv_token_capacity(0.0), 0);
        let c = cfg(kv, 0.0, MemoryPolicy::NoOffload, 4);
        let reqs = fixed_requests(10, 32, 4, 0.01);
        let rep = simulate(&c, &reqs);
        assert_eq!(rep.rejected, 10);
        assert!(rep.outcomes.is_empty());
    }

    #[test]
    fn oversized_prompt_is_rejected_not_deadlocked() {
        // 4 pages = 64 tokens of HBM; a 100-token prompt can never fit
        let mut c = cfg(tiny_kv(4), 0.0, MemoryPolicy::NoOffload, 2);
        c.max_seq = 512;
        let mut reqs = fixed_requests(3, 16, 4, 0.01);
        reqs[1].prompt_tokens = 100;
        let rep = simulate(&c, &reqs);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.outcomes.len(), 2);
    }

    #[test]
    fn cost_model_matches_planner_decode_latency() {
        let kv = KvCacheConfig::llama8b_910c();
        for &(n, f) in &[(10_000usize, 0.0), (71_000, 0.0), (50_000, 0.3)] {
            let mut cm = CostModel::new(kv.clone(), f);
            cm.iteration_overhead = 0.0;
            let a = cm.iteration_latency(n, 0, 0);
            let b = kv.decode_latency(n, f);
            assert!(
                (a - b).abs() < 1e-15,
                "batch cost model must agree with the closed-form planner: {a} vs {b}"
            );
        }
    }

    #[test]
    fn trace_intervals_never_overlap_per_replica() {
        let mut c = cfg(tiny_kv(32), 0.0, MemoryPolicy::NoOffload, 4);
        c.fleet = 3;
        let reqs = fixed_requests(60, 32, 10, 0.003);
        let rep = simulate(&c, &reqs);
        assert_eq!(rep.trace.resources(), 3);
        for r in 0..3 {
            let bucket = rep.trace.per_resource(ResourceId(r));
            assert!(bucket.windows(2).all(|w| w[0].finish <= w[1].start + 1e-12));
        }
        // every replica served something under least-loaded routing
        for r in 0..3 {
            assert!(rep.trace.busy_time(ResourceId(r)) > 0.0);
        }
    }

    #[test]
    fn streaming_sink_matches_indexed_bitwise() {
        // same scenario under both sinks: every report number and the
        // shared accumulator statistics must agree to the bit
        let mut c = cfg(tiny_kv(16), 0.1, MemoryPolicy::PoolOffload, 6);
        c.fleet = 3;
        let reqs = fixed_requests(60, 48, 12, 1e-4);
        let a = simulate(&c, &reqs);
        c.trace_mode = TraceMode::Streaming;
        let b = simulate(&c, &reqs);
        assert_eq!(a.trace.mode(), TraceMode::Indexed);
        assert_eq!(b.trace.mode(), TraceMode::Streaming);
        assert!(b.trace.indexed().is_none(), "streaming must not keep the log");
        for ((ka, va), (kb, vb)) in a.summary_kv().iter().zip(&b.summary_kv()) {
            assert_eq!(ka, kb);
            assert_eq!(va.to_bits(), vb.to_bits(), "summary row {ka} drifted");
        }
        assert_eq!(a.trace.interval_count(), b.trace.interval_count());
        for r in 0..3 {
            let r = ResourceId(r);
            assert_eq!(
                a.trace.busy_time(r).to_bits(),
                b.trace.busy_time(r).to_bits()
            );
        }
        for tag in a.trace.tag_values() {
            assert_eq!(a.trace.tagged_count(tag), b.trace.tagged_count(tag));
            assert_eq!(
                a.trace.tagged_busy(tag).to_bits(),
                b.trace.tagged_busy(tag).to_bits()
            );
        }
    }
}
