//! Fleet-wide fault injection (ISSUE 6).
//!
//! The supernode-as-one-computer premise only survives contact with a
//! 384-accelerator pool if the framework reacts to the faults such a
//! pool makes routine: links that degrade or flap, and training
//! devices that die mid-step. This module is the single, deterministic
//! description of *what goes wrong when* — a [`FaultPlan`] scheduled
//! on the shared virtual clock — consumed by every layer:
//!
//! - **fabric faults** ([`LinkDegrade`], [`FaultPlan::link_flap`]) —
//!   windowed bandwidth/latency scaling of one [`LinkTier`], priced
//!   through [`FaultPlan::effective_topology`] so KV migrations,
//!   warm-up weight loads, resharding all-to-alls and gradient
//!   all-reduces all slow down for real;
//! - **training-device failures** ([`DeviceFail`]) — revoke a leased
//!   device mid-step; `hypermpmd::coschedule` aborts the step and
//!   recovers via checkpoint-restore (MTTR and steps-lost land in the
//!   train report);
//! - **serving resilience** ([`RetryPolicy`]) — router-level retry
//!   with timeout + backoff, plus straggler-aware hedging away from
//!   destinations on degraded links (`serving::cluster`);
//! - **chaos harness** ([`chaos`]) — seeded random fault schedules
//!   with global invariants asserted under every one.
//!
//! Pricing is *at dispatch*: a transfer in flight when a window opens
//! keeps the price it was quoted, exactly like the Python mirrors
//! (`tools/cluster_simcheck.py` / `tools/cosched_simcheck.py`), which
//! keep fault-free runs bit-identical to the pre-fault code paths.

use crate::supernode::{Fabric, Fleet, FleetPool, LinkSpec, LinkTier, Topology};

pub mod chaos;

/// One windowed degradation of a link tier: over `[start, end)` the
/// tier's bandwidth is multiplied by `bandwidth_scale` (< 1 slows it
/// down) and its per-hop latency by `latency_scale` (> 1 slows it
/// down). Overlapping windows on the same tier compose
/// multiplicatively.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkDegrade {
    pub tier: LinkTier,
    /// Window start (inclusive), seconds of virtual time.
    pub start: f64,
    /// Window end (exclusive).
    pub end: f64,
    /// Multiplier on the tier's bandwidth (0 < scale ≤ 1 degrades).
    pub bandwidth_scale: f64,
    /// Multiplier on the tier's per-hop latency (≥ 1 degrades).
    pub latency_scale: f64,
}

impl LinkDegrade {
    /// Does this window cover virtual time `t`? Half-open `[start, end)`.
    pub fn covers(&self, t: f64) -> bool {
        self.start <= t && t < self.end
    }
}

/// Kill one *training* device at `time`. Like `InstanceCrash`, the
/// target is ordinal over the trainer's lease at fail time (absolute
/// ids would race against elastic lease churn); a fail landing on an
/// empty lease is a no-op — free and serving-held devices are covered
/// by the serving tenant's own crash model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceFail {
    pub time: f64,
    pub ordinal: u64,
}

/// A deterministic fault schedule on the shared virtual clock.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub link_windows: Vec<LinkDegrade>,
    pub device_fails: Vec<DeviceFail>,
}

impl FaultPlan {
    /// The fault-free plan.
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.link_windows.is_empty() && self.device_fails.is_empty()
    }

    /// A flapping link: `count` equal degrade windows of length `down`
    /// separated by `up` seconds of clean fabric, starting at
    /// `first_start`. Latency is left alone — a flap starves
    /// bandwidth; pair with explicit [`LinkDegrade`] windows when the
    /// latency should spike too.
    pub fn link_flap(
        tier: LinkTier,
        first_start: f64,
        up: f64,
        down: f64,
        count: usize,
        bandwidth_scale: f64,
    ) -> Self {
        let mut plan = Self::empty();
        let mut start = first_start;
        for _ in 0..count {
            plan.link_windows.push(LinkDegrade {
                tier,
                start,
                end: start + down,
                bandwidth_scale,
                latency_scale: 1.0,
            });
            start += down + up;
        }
        plan
    }

    /// The `(bandwidth, latency)` multipliers in force on `tier` at
    /// time `t`: the product over every covering window, in plan
    /// order. `(1.0, 1.0)` on clean fabric.
    pub fn scale_at(&self, tier: LinkTier, t: f64) -> (f64, f64) {
        let mut bw = 1.0;
        let mut lat = 1.0;
        for w in &self.link_windows {
            if w.tier == tier && w.covers(t) {
                bw *= w.bandwidth_scale;
                lat *= w.latency_scale;
            }
        }
        (bw, lat)
    }

    /// Is *any* tier degraded at `t`? Gates the fault pricing path (and
    /// router hedging) so fault-free runs never construct an effective
    /// fabric — bit-identical to the pre-fault code.
    pub fn degraded_at(&self, t: f64) -> bool {
        self.link_windows.iter().any(|w| w.covers(t))
    }

    /// `base` with the scales in force on `tier` at `t` applied.
    pub fn effective_spec(&self, base: LinkSpec, tier: LinkTier, t: f64) -> LinkSpec {
        let (bw, lat) = self.scale_at(tier, t);
        LinkSpec {
            bandwidth: base.bandwidth * bw,
            hop_latency: base.hop_latency * lat,
            hops: base.hops,
        }
    }

    /// The fabric as degraded at time `t`. The name is preserved so
    /// algorithm selection (`collectives::cost` offers the mesh
    /// algorithm on supernode fabrics only) is unchanged by faults.
    pub fn effective_fabric(&self, base: &Fabric, t: f64) -> Fabric {
        Fabric {
            name: base.name,
            local: self.effective_spec(base.local, LinkTier::Local, t),
            board: self.effective_spec(base.board, LinkTier::Board, t),
            rack: self.effective_spec(base.rack, LinkTier::Rack, t),
            cross_rack: self.effective_spec(base.cross_rack, LinkTier::CrossRack, t),
        }
    }

    /// The topology as degraded at time `t` — same geometry and
    /// devices, fabric swapped for [`FaultPlan::effective_fabric`].
    /// Feed this to `collectives::cost` / `Topology::p2p_time` to
    /// price a transfer dispatched at `t`.
    pub fn effective_topology(&self, base: &Topology, t: f64) -> Topology {
        Topology {
            geometry: base.geometry,
            fabric: self.effective_fabric(&base.fabric, t),
            devices: base.devices.clone(),
        }
    }

    /// The fleet as degraded at time `t`: every pool's fabric gets its
    /// tier windows applied, and the inter-supernode link its
    /// [`LinkTier::InterNode`] windows — so a DCN brownout is one more
    /// scheduled fault, priced through `collectives::cost_fleet` like
    /// everything else.
    pub fn effective_fleet(&self, base: &Fleet, t: f64) -> Fleet {
        Fleet::new(
            base.pools
                .iter()
                .map(|p| FleetPool {
                    name: p.name.clone(),
                    topo: self.effective_topology(&p.topo, t),
                })
                .collect(),
            self.effective_spec(base.inter, LinkTier::InterNode, t),
        )
    }
}

/// Serving-side resilience knobs (ISSUE 6 tentpole #3): how the
/// cluster reacts when a KV migration is priced over a degraded link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// A migration whose priced transfer exceeds this is parked and
    /// re-routed instead of dispatched, seconds.
    pub timeout: f64,
    /// Extra wait per prior attempt before the re-route fires.
    pub backoff: f64,
    /// Re-routes before the slow path is accepted as-is.
    pub max_attempts: u32,
    /// Hedging: prefer destinations whose degraded path is within
    /// `hedge`× their clean transfer time (≤ 0 disables hedging).
    pub hedge: f64,
}

impl RetryPolicy {
    /// The preset the checked-in fault scenarios run with: park a
    /// migration slower than 5 ms, back off 2.5 ms per attempt, accept
    /// the slow path after 2 re-routes, hedge away from destinations
    /// >2× their clean path.
    pub fn degraded_fabric() -> Self {
        Self {
            timeout: 0.005,
            backoff: 0.0025,
            max_attempts: 2,
            hedge: 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_never_degrades() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        for t in [0.0, 1.0, 1e6] {
            assert!(!p.degraded_at(t));
            assert_eq!(p.scale_at(LinkTier::Rack, t), (1.0, 1.0));
        }
    }

    #[test]
    fn window_is_half_open() {
        let w = LinkDegrade {
            tier: LinkTier::Rack,
            start: 2.0,
            end: 5.0,
            bandwidth_scale: 0.1,
            latency_scale: 10.0,
        };
        let p = FaultPlan {
            link_windows: vec![w],
            device_fails: vec![],
        };
        assert!(!p.degraded_at(1.999));
        assert!(p.degraded_at(2.0));
        assert!(p.degraded_at(4.999));
        assert!(!p.degraded_at(5.0));
        assert_eq!(p.scale_at(LinkTier::Rack, 3.0), (0.1, 10.0));
        // other tiers untouched
        assert_eq!(p.scale_at(LinkTier::Board, 3.0), (1.0, 1.0));
    }

    #[test]
    fn overlapping_windows_compose_multiplicatively() {
        let p = FaultPlan {
            link_windows: vec![
                LinkDegrade {
                    tier: LinkTier::Board,
                    start: 0.0,
                    end: 10.0,
                    bandwidth_scale: 0.5,
                    latency_scale: 2.0,
                },
                LinkDegrade {
                    tier: LinkTier::Board,
                    start: 5.0,
                    end: 15.0,
                    bandwidth_scale: 0.5,
                    latency_scale: 3.0,
                },
            ],
            device_fails: vec![],
        };
        assert_eq!(p.scale_at(LinkTier::Board, 7.0), (0.25, 6.0));
        assert_eq!(p.scale_at(LinkTier::Board, 12.0), (0.5, 3.0));
    }

    #[test]
    fn link_flap_alternates_windows() {
        let p = FaultPlan::link_flap(LinkTier::CrossRack, 1.0, 2.0, 0.5, 3, 0.05);
        assert_eq!(p.link_windows.len(), 3);
        // down [1.0,1.5), up, down [3.5,4.0), up, down [6.0,6.5)
        assert!(p.degraded_at(1.2));
        assert!(!p.degraded_at(2.0));
        assert!(p.degraded_at(3.7));
        assert!(!p.degraded_at(5.0));
        assert!(p.degraded_at(6.4));
        assert!(!p.degraded_at(6.5));
        let (bw, lat) = p.scale_at(LinkTier::CrossRack, 1.2);
        assert_eq!((bw, lat), (0.05, 1.0));
    }

    #[test]
    fn effective_fabric_scales_only_covered_tiers() {
        let base = Fabric::supernode();
        let p = FaultPlan {
            link_windows: vec![LinkDegrade {
                tier: LinkTier::Rack,
                start: 0.0,
                end: 1.0,
                bandwidth_scale: 0.1,
                latency_scale: 10.0,
            }],
            device_fails: vec![],
        };
        let eff = p.effective_fabric(&base, 0.5);
        assert_eq!(eff.name, base.name);
        assert_eq!(eff.rack.bandwidth, base.rack.bandwidth * 0.1);
        assert_eq!(eff.rack.hop_latency, base.rack.hop_latency * 10.0);
        assert_eq!(eff.rack.hops, base.rack.hops);
        assert_eq!(eff.board, base.board);
        assert_eq!(eff.cross_rack, base.cross_rack);
        // outside the window the fabric is bitwise the base
        assert_eq!(p.effective_fabric(&base, 1.0), base);
    }

    #[test]
    fn effective_topology_prices_transfers_slower() {
        let topo = Topology::tiny();
        let p = FaultPlan {
            link_windows: vec![LinkDegrade {
                tier: LinkTier::Board,
                start: 0.0,
                end: 1.0,
                bandwidth_scale: 0.1,
                latency_scale: 1.0,
            }],
            device_fails: vec![],
        };
        let eff = p.effective_topology(&topo, 0.5);
        let a = topo.devices[0].id;
        let b = topo.devices[1].id;
        let clean = topo.p2p_time(a, b, 1e9);
        let slow = eff.p2p_time(a, b, 1e9);
        assert!(slow > 5.0 * clean, "slow={slow} clean={clean}");
    }

    #[test]
    fn degraded_fabric_preset() {
        let r = RetryPolicy::degraded_fabric();
        assert_eq!(r.timeout, 0.005);
        assert_eq!(r.backoff, 0.0025);
        assert_eq!(r.max_attempts, 2);
        assert_eq!(r.hedge, 2.0);
    }
}
