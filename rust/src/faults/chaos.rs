//! Chaos harness (ISSUE 6 tentpole #4): seeded random fault schedules
//! plus the checked-in acceptance scenario.
//!
//! [`random_plan`] draws 1–3 link-degrade windows, 0–2 training-device
//! fails and 0–1 serving-instance crashes from the repo RNG — the draw
//! order is mirrored verbatim by `tools/cosched_simcheck.py`'s
//! `random_plan`, so the Rust chaos suite and the Python calibrator
//! see identical schedules for identical seeds. The property tests in
//! `tests/fault_scenarios.rs` run ≥ 16 such schedules through the
//! co-scheduled PR 5 setup and assert the global invariants (request
//! conservation, lease-ledger partition, page custody, tenant
//! overlap-freedom) hold under every one.

use super::{DeviceFail, FaultPlan, LinkDegrade};
use crate::serving::InstanceCrash;
use crate::supernode::LinkTier;
use crate::util::rng::Rng;

/// The checked-in seed-42 acceptance scenario: one training
/// `DeviceFail` at t=18 s, plus a 10× rack-tier degrade (1/10 the
/// bandwidth, 10× the hop latency) over `[20, 26)` s — both landing
/// inside the PR 5 co-scheduled run's 48 s horizon.
pub fn fault_scenario_plan() -> FaultPlan {
    FaultPlan {
        link_windows: vec![LinkDegrade {
            tier: LinkTier::Rack,
            start: 20.0,
            end: 26.0,
            bandwidth_scale: 0.1,
            latency_scale: 10.0,
        }],
        device_fails: vec![DeviceFail {
            time: 18.0,
            ordinal: 3,
        }],
    }
}

/// Horizon the chaos property suite runs at (shortened from the 48 s
/// acceptance scenario so 16+ seeds stay inside the CI timeout).
pub const CHAOS_HORIZON: f64 = 12.0;

/// Seeds the checked-in chaos suite iterates.
pub const CHAOS_SEEDS: u64 = 16;

/// A seeded random fault schedule over `[0, horizon)`: 1–3 link
/// windows (tier, start in the first 60%, 5–30% of the horizon long,
/// bandwidth cut to 2–20%, latency 1–10×), 0–2 training-device fails
/// and 0–1 serving-instance crashes in the middle 80%. Returns the
/// [`FaultPlan`] plus the crash list for `ClusterConfig::failures`.
pub fn random_plan(seed: u64, horizon: f64) -> (FaultPlan, Vec<InstanceCrash>) {
    random_plan_with_tiers(
        seed,
        horizon,
        &[LinkTier::Board, LinkTier::Rack, LinkTier::CrossRack],
    )
}

/// [`random_plan`] extended with the fleet dimension (ISSUE 9): the
/// tier draw includes [`LinkTier::InterNode`], so a schedule can
/// degrade the inter-supernode link itself. Same draw order, one more
/// face on the tier die — mirrored by `tools/cosched_simcheck.py`'s
/// `random_fleet_plan`.
pub fn random_fleet_plan(seed: u64, horizon: f64) -> (FaultPlan, Vec<InstanceCrash>) {
    random_plan_with_tiers(
        seed,
        horizon,
        &[
            LinkTier::Board,
            LinkTier::Rack,
            LinkTier::CrossRack,
            LinkTier::InterNode,
        ],
    )
}

fn random_plan_with_tiers(
    seed: u64,
    horizon: f64,
    tiers: &[LinkTier],
) -> (FaultPlan, Vec<InstanceCrash>) {
    let mut rng = Rng::new(seed);
    let mut plan = FaultPlan::empty();
    let n_links = 1 + rng.below(3);
    for _ in 0..n_links {
        let tier = tiers[rng.below(tiers.len() as u64) as usize];
        let start = rng.next_f64() * 0.6 * horizon;
        let dur = (0.05 + 0.25 * rng.next_f64()) * horizon;
        let bandwidth_scale = 0.02 + 0.18 * rng.next_f64();
        let latency_scale = 1.0 + 9.0 * rng.next_f64();
        plan.link_windows.push(LinkDegrade {
            tier,
            start,
            end: start + dur,
            bandwidth_scale,
            latency_scale,
        });
    }
    let n_fails = rng.below(3);
    for _ in 0..n_fails {
        let time = (0.1 + 0.8 * rng.next_f64()) * horizon;
        let ordinal = rng.below(64);
        plan.device_fails.push(DeviceFail { time, ordinal });
    }
    let mut crashes = Vec::new();
    let n_crashes = rng.below(2);
    for _ in 0..n_crashes {
        let time = (0.1 + 0.8 * rng.next_f64()) * horizon;
        let instance = rng.below(8) as usize;
        crashes.push(InstanceCrash { time, instance });
    }
    (plan, crashes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_plan_is_deterministic_per_seed() {
        let (a, ca) = random_plan(7, CHAOS_HORIZON);
        let (b, cb) = random_plan(7, CHAOS_HORIZON);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
        let (c, _) = random_plan(8, CHAOS_HORIZON);
        assert_ne!(a, c);
    }

    #[test]
    fn random_plans_stay_in_bounds() {
        for seed in 0..CHAOS_SEEDS {
            let (plan, crashes) = random_plan(seed, CHAOS_HORIZON);
            assert!((1..=3).contains(&plan.link_windows.len()));
            assert!(plan.device_fails.len() <= 2);
            assert!(crashes.len() <= 1);
            for w in &plan.link_windows {
                assert!(w.tier != LinkTier::Local);
                assert!(w.start >= 0.0 && w.start <= 0.6 * CHAOS_HORIZON);
                assert!(w.end > w.start);
                assert!(w.end - w.start <= 0.3 * CHAOS_HORIZON + 1e-9);
                assert!((0.02..=0.2).contains(&w.bandwidth_scale));
                assert!((1.0..=10.0).contains(&w.latency_scale));
            }
            for f in &plan.device_fails {
                assert!(f.time >= 0.1 * CHAOS_HORIZON && f.time <= 0.9 * CHAOS_HORIZON);
                assert!(f.ordinal < 64);
            }
            for c in &crashes {
                assert!(c.time >= 0.1 * CHAOS_HORIZON && c.time <= 0.9 * CHAOS_HORIZON);
                assert!(c.instance < 8);
            }
        }
    }

    #[test]
    fn fleet_plan_adds_the_inter_node_face() {
        // deterministic per seed, and across the suite's seed range the
        // extra die face actually lands: some schedule degrades the
        // inter-supernode link
        let (a, ca) = random_fleet_plan(7, CHAOS_HORIZON);
        let (b, cb) = random_fleet_plan(7, CHAOS_HORIZON);
        assert_eq!(a, b);
        assert_eq!(ca, cb);
        let mut saw_inter = false;
        for seed in 0..CHAOS_SEEDS {
            let (plan, crashes) = random_fleet_plan(seed, CHAOS_HORIZON);
            assert!((1..=3).contains(&plan.link_windows.len()));
            assert!(plan.device_fails.len() <= 2);
            assert!(crashes.len() <= 1);
            saw_inter |= plan
                .link_windows
                .iter()
                .any(|w| w.tier == LinkTier::InterNode);
        }
        assert!(saw_inter, "no seed in 0..{CHAOS_SEEDS} drew InterNode");
    }

    #[test]
    fn acceptance_scenario_shape() {
        let plan = fault_scenario_plan();
        assert_eq!(plan.link_windows.len(), 1);
        assert_eq!(plan.device_fails.len(), 1);
        let w = plan.link_windows[0];
        assert_eq!(w.tier, LinkTier::Rack);
        assert!(plan.degraded_at(23.0) && !plan.degraded_at(26.0));
        // the fail lands before the degrade window opens
        assert!(plan.device_fails[0].time < w.start);
    }
}
