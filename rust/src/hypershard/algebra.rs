//! Composable strategy algebra (ISSUE 10).
//!
//! The planner (`hypershard::planner`) *enumerates* strategies; this
//! module lets callers *write* them: a small expression language over
//! the paper's Table 1 dimensions, closed under composition, with a
//! normalizer that lowers every well-formed term to the concrete
//! artifacts the rest of the framework prices —
//!
//! - a [`ParallelStrategy`] (the normal form's dimension sizes),
//! - a [`RankGrid`] via `planner::try_assign_ranks` (device ranks),
//! - a [`PipelineSchedule`] for the `Pp` term (GPipe vs 1F1B),
//! - for fleets, a compute-proportional device *placement* honoring
//!   `OnPool` constraints (via `heterogeneous::try_proportional_partition`).
//!
//! Grammar (see DESIGN.md "Strategy algebra" for the lowering rules):
//!
//! ```text
//! expr ::= Dp(n) | Tp(n) | Pp(n) | Ep(n) | Cp(n)   sized atoms
//!        | Sp | Fsdp | Mpmd                         flag atoms
//!        | Seq([expr, ...])                         composition
//!        | Nest(expr, expr)                         outer(inner) nesting
//!        | OnPool("name[,name...]", expr)           placement constraint
//! ```
//!
//! Seq and Nest both lower by *dimension product* (sizes multiply per
//! dimension, flags OR) — the rank-grid layout is fixed by
//! `try_assign_ranks` (TP innermost), so nesting order affects the
//! surface syntax and `describe()` only, never the priced plan. This
//! is deliberate: the algebra's laws (`Seq` is associative with
//! identity `Seq([])`, `Nest(a, b) ≡ Seq([a, b])` after lowering) are
//! what make auto-search over terms tractable.
//!
//! Malformed terms — zero-sized dims, `usize` overflow, unknown or
//! conflicting pool names, a strategy that does not cover the cluster
//! — normalize or lower to `Err(String)`, never a panic
//! (property-tested in `rust/tests/property_algebra.rs`).

use super::heterogeneous::try_proportional_partition;
use super::planner::{try_assign_ranks, try_evaluate, PlanCandidate, PlannerConfig, RankGrid};
use super::strategies::ParallelStrategy;
use crate::config::ModelDesc;
use crate::supernode::{DeviceId, Fleet, Topology};
use crate::trainer::PipelineSchedule;

/// A composable strategy expression. See the module docs for the
/// grammar and DESIGN.md for the lowering rules.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StrategyExpr {
    /// Data parallelism of the given degree.
    Dp(usize),
    /// Tensor parallelism of the given degree.
    Tp(usize),
    /// Pipeline parallelism of the given degree.
    Pp(usize),
    /// Expert parallelism (DeepSeek-style EP ⊆ DP: does not multiply
    /// the device count).
    Ep(usize),
    /// Context parallelism of the given degree.
    Cp(usize),
    /// Sequence parallelism (piggybacks on the TP group).
    Sp,
    /// ZeRO-3-style fully sharded data parallelism.
    Fsdp,
    /// Task-level MPMD parallelism.
    Mpmd,
    /// Sequential composition: dimension sizes multiply, flags OR.
    /// `Seq([])` is the identity strategy (all dims 1).
    Seq(Vec<StrategyExpr>),
    /// Nested composition `outer(inner)` — same normal form as
    /// `Seq([outer, inner])`; kept in the surface syntax so terms read
    /// the way strategies are spoken ("DP over TP8 boards").
    Nest(Box<StrategyExpr>, Box<StrategyExpr>),
    /// Constrain the sub-expression's devices to the named fleet pools
    /// (comma-separated pool names, e.g. `"910c"` or `"910c,910b"`).
    OnPool(String, Box<StrategyExpr>),
}

impl StrategyExpr {
    /// Convenience constructor for [`StrategyExpr::Nest`].
    pub fn nest(outer: StrategyExpr, inner: StrategyExpr) -> Self {
        Self::Nest(Box::new(outer), Box::new(inner))
    }

    /// Convenience constructor for [`StrategyExpr::OnPool`].
    pub fn on_pool(pools: &str, expr: StrategyExpr) -> Self {
        Self::OnPool(pools.to_string(), Box::new(expr))
    }

    /// Syntactic rendering of the term (pre-normalization).
    pub fn render(&self) -> String {
        match self {
            Self::Dp(n) => format!("Dp({n})"),
            Self::Tp(n) => format!("Tp({n})"),
            Self::Pp(n) => format!("Pp({n})"),
            Self::Ep(n) => format!("Ep({n})"),
            Self::Cp(n) => format!("Cp({n})"),
            Self::Sp => "Sp".to_string(),
            Self::Fsdp => "Fsdp".to_string(),
            Self::Mpmd => "Mpmd".to_string(),
            Self::Seq(xs) => {
                let parts: Vec<String> = xs.iter().map(Self::render).collect();
                format!("Seq[{}]", parts.join(", "))
            }
            Self::Nest(a, b) => format!("{}({})", a.render(), b.render()),
            Self::OnPool(p, e) => format!("OnPool({p}, {})", e.render()),
        }
    }
}

/// The normal form of a well-formed expression: concrete dimension
/// sizes plus the (possibly empty) pool-placement constraint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NormalForm {
    pub strategy: ParallelStrategy,
    /// Pool names the term is constrained to; empty = whole fleet
    /// (or a bare topology).
    pub pools: Vec<String>,
}

impl NormalForm {
    /// Canonical label: equal normal forms render equally, so the
    /// auto-tuner dedups candidate terms by this string.
    pub fn describe(&self) -> String {
        if self.pools.is_empty() {
            self.strategy.describe()
        } else {
            format!("{} @{}", self.strategy.describe(), self.pools.join(","))
        }
    }
}

fn parse_pools(pattern: &str) -> Result<Vec<String>, String> {
    let names: Vec<String> = pattern
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if names.is_empty() {
        return Err(format!("empty pool pattern {pattern:?}"));
    }
    Ok(names)
}

fn mul_dim(name: &str, a: usize, b: usize) -> Result<usize, String> {
    a.checked_mul(b)
        .ok_or_else(|| format!("{name} degree overflows usize ({a} x {b})"))
}

fn combine(a: NormalForm, b: NormalForm) -> Result<NormalForm, String> {
    if !a.pools.is_empty() && !b.pools.is_empty() && a.pools != b.pools {
        return Err(format!(
            "conflicting pool placements {:?} and {:?} in one term",
            a.pools, b.pools
        ));
    }
    let pools = if a.pools.is_empty() { b.pools } else { a.pools };
    let (sa, sb) = (a.strategy, b.strategy);
    let strategy = ParallelStrategy {
        dp: mul_dim("dp", sa.dp, sb.dp)?,
        tp: mul_dim("tp", sa.tp, sb.tp)?,
        pp: mul_dim("pp", sa.pp, sb.pp)?,
        ep: mul_dim("ep", sa.ep, sb.ep)?,
        cp: mul_dim("cp", sa.cp, sb.cp)?,
        sp: sa.sp || sb.sp,
        fsdp: sa.fsdp || sb.fsdp,
        mpmd: sa.mpmd || sb.mpmd,
    };
    // the total device count must stay representable too
    strategy
        .dp
        .checked_mul(strategy.tp)
        .and_then(|x| x.checked_mul(strategy.pp))
        .and_then(|x| x.checked_mul(strategy.cp))
        .ok_or_else(|| "device count overflows usize".to_string())?;
    Ok(NormalForm { strategy, pools })
}

fn sized(
    name: &str,
    n: usize,
    set: impl FnOnce(&mut ParallelStrategy),
) -> Result<NormalForm, String> {
    if n == 0 {
        return Err(format!("{name}(0) is malformed: dimension degrees are >= 1"));
    }
    let mut strategy = ParallelStrategy::default();
    set(&mut strategy);
    Ok(NormalForm {
        strategy,
        pools: Vec::new(),
    })
}

/// Normalize an expression: fold every combinator down to one
/// [`ParallelStrategy`] plus the pool constraint. Malformed terms
/// (zero dims, overflow, empty/conflicting pool patterns) are `Err`.
pub fn normalize(expr: &StrategyExpr) -> Result<NormalForm, String> {
    match expr {
        StrategyExpr::Dp(n) => sized("Dp", *n, |s| s.dp = *n),
        StrategyExpr::Tp(n) => sized("Tp", *n, |s| s.tp = *n),
        StrategyExpr::Pp(n) => sized("Pp", *n, |s| s.pp = *n),
        StrategyExpr::Ep(n) => sized("Ep", *n, |s| s.ep = *n),
        StrategyExpr::Cp(n) => sized("Cp", *n, |s| s.cp = *n),
        StrategyExpr::Sp => sized("Sp", 1, |s| s.sp = true),
        StrategyExpr::Fsdp => sized("Fsdp", 1, |s| s.fsdp = true),
        StrategyExpr::Mpmd => sized("Mpmd", 1, |s| s.mpmd = true),
        StrategyExpr::Seq(xs) => {
            let mut acc = NormalForm {
                strategy: ParallelStrategy::default(),
                pools: Vec::new(),
            };
            for x in xs {
                acc = combine(acc, normalize(x)?)?;
            }
            Ok(acc)
        }
        StrategyExpr::Nest(a, b) => combine(normalize(a)?, normalize(b)?),
        StrategyExpr::OnPool(pattern, e) => {
            let pools = parse_pools(pattern)?;
            let inner = normalize(e)?;
            if !inner.pools.is_empty() && inner.pools != pools {
                return Err(format!(
                    "conflicting pool placements {:?} and {:?} in one term",
                    pools, inner.pools
                ));
            }
            Ok(NormalForm {
                strategy: inner.strategy,
                pools,
            })
        }
    }
}

/// A term lowered against a bare [`Topology`]: the normal form plus
/// the rank grid and the pipeline schedule its `Pp` term runs.
#[derive(Debug, Clone)]
pub struct LoweredPlan {
    pub strategy: ParallelStrategy,
    pub grid: RankGrid,
    pub schedule: PipelineSchedule,
    pub microbatches: usize,
}

/// Lower a term onto a topology: normalize, check the strategy covers
/// the cluster exactly (`try_assign_ranks`), and select the pipeline
/// schedule for the `Pp` term. `OnPool` terms need a fleet — they are
/// an `Err` here, pointing at [`lower_fleet`].
pub fn lower(
    expr: &StrategyExpr,
    topo: &Topology,
    cfg: &PlannerConfig,
) -> Result<LoweredPlan, String> {
    let nf = normalize(expr)?;
    if !nf.pools.is_empty() {
        return Err(format!(
            "term is pool-constrained to {:?}; lower it over a Fleet with lower_fleet",
            nf.pools
        ));
    }
    let grid = try_assign_ranks(&nf.strategy, topo.device_count())?;
    let schedule = PipelineSchedule::select(nf.strategy.pp, cfg.microbatches);
    Ok(LoweredPlan {
        strategy: nf.strategy,
        grid,
        schedule,
        microbatches: cfg.microbatches,
    })
}

/// Price a term over a topology through the planner's cost model:
/// lower, then `planner::try_evaluate` the normal form. This is what
/// makes every well-formed term exactly as priceable as a hand-built
/// [`ParallelStrategy`].
pub fn evaluate_expr(
    model: &ModelDesc,
    topo: &Topology,
    expr: &StrategyExpr,
    cfg: &PlannerConfig,
) -> Result<PlanCandidate, String> {
    let plan = lower(expr, topo, cfg)?;
    try_evaluate(model, topo, &plan.strategy, cfg)
}

/// A term lowered against a [`Fleet`]: the normal form plus a concrete
/// fleet-global device group, apportioned compute-proportionally over
/// the placed pools.
#[derive(Debug, Clone)]
pub struct FleetLoweredPlan {
    pub strategy: ParallelStrategy,
    /// Indices of the pools the term was placed on.
    pub pool_indices: Vec<usize>,
    /// Devices taken from each placed pool (same order as
    /// `pool_indices`; sums to the strategy's device count).
    pub per_pool: Vec<usize>,
    /// The fleet-global device group, ascending id order — so a term
    /// spanning a whole pool (or fleet) yields *exactly* the group the
    /// hand-written presets use, keeping their costs bit-identical.
    pub group: Vec<DeviceId>,
    pub schedule: PipelineSchedule,
    pub microbatches: usize,
}

/// Lower a term onto a fleet. The strategy's device count is
/// apportioned over the placed pools by compute weight (largest-
/// remainder, capped by pool sizes — `try_proportional_partition`);
/// within each pool the fastest devices are taken (ties to the lowest
/// id) and the group is emitted in ascending global-id order. Unknown
/// pool names and infeasible device counts are `Err`.
pub fn lower_fleet(
    expr: &StrategyExpr,
    fleet: &Fleet,
    cfg: &PlannerConfig,
) -> Result<FleetLoweredPlan, String> {
    let nf = normalize(expr)?;
    let pool_indices: Vec<usize> = if nf.pools.is_empty() {
        (0..fleet.pool_count()).collect()
    } else {
        let known: Vec<&str> = fleet.pools.iter().map(|p| p.name.as_str()).collect();
        let mut idx = Vec::with_capacity(nf.pools.len());
        for name in &nf.pools {
            match known.iter().position(|k| k == name) {
                Some(i) => {
                    if idx.contains(&i) {
                        return Err(format!("pool {name:?} named twice in placement"));
                    }
                    idx.push(i);
                }
                None => {
                    return Err(format!(
                        "unknown pool {name:?}; fleet pools are {known:?}"
                    ))
                }
            }
        }
        idx
    };

    let n = nf.strategy.device_count();
    let available: usize = pool_indices
        .iter()
        .map(|&p| fleet.pools[p].topo.device_count())
        .sum();
    // sub-pool groups are legitimate for elastic tenants (the fastest
    // subset is taken), so unlike try_assign_ranks only
    // over-subscription is rejected here
    if n > available {
        return Err(format!(
            "strategy covers {n} devices but the placed pools have only {available}"
        ));
    }
    // apportion over pools by aggregate compute (cube FLOPs), capped
    // by each pool's device count
    let weights: Vec<f64> = pool_indices
        .iter()
        .map(|&p| {
            fleet.pools[p]
                .topo
                .devices
                .iter()
                .map(|d| d.spec.cube_flops)
                .sum()
        })
        .collect();
    let caps: Vec<usize> = pool_indices
        .iter()
        .map(|&p| fleet.pools[p].topo.device_count())
        .collect();
    let per_pool = try_proportional_partition(n, &weights, Some(&caps))?;

    let mut group: Vec<DeviceId> = Vec::with_capacity(n);
    for (k, &p) in pool_indices.iter().enumerate() {
        let devices = fleet.pool_devices(p);
        let take = per_pool[k];
        // fastest `take` devices of the pool; ties break to the lowest
        // global id, and the chosen subset is emitted in ascending id
        // order so full-pool groups equal the preset groups exactly
        let mut order: Vec<usize> = (0..devices.len()).collect();
        order.sort_by(|&a, &b| {
            fleet
                .spec(devices[b])
                .cube_flops
                .total_cmp(&fleet.spec(devices[a]).cube_flops)
                .then(a.cmp(&b))
        });
        let mut chosen: Vec<DeviceId> = order[..take].iter().map(|&i| devices[i]).collect();
        chosen.sort();
        group.extend(chosen);
    }
    let schedule = PipelineSchedule::select(nf.strategy.pp, cfg.microbatches);
    Ok(FleetLoweredPlan {
        strategy: nf.strategy,
        pool_indices,
        per_pool,
        group,
        schedule,
        microbatches: cfg.microbatches,
    })
}

/// Price a term's gradient-sync collective over a fleet: lower, then
/// `collectives::cost_fleet` an all-reduce of `bytes` over the placed
/// group — the fleet-side analogue of [`evaluate_expr`]'s comm terms.
pub fn fleet_sync_time(
    expr: &StrategyExpr,
    fleet: &Fleet,
    cfg: &PlannerConfig,
    bytes: f64,
) -> Result<f64, String> {
    let plan = lower_fleet(expr, fleet, cfg)?;
    if plan.group.len() <= 1 {
        return Ok(0.0);
    }
    Ok(crate::collectives::cost_fleet(
        fleet,
        crate::graph::CollectiveKind::AllReduce,
        bytes,
        &plan.group,
    )
    .time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use StrategyExpr::*;

    #[test]
    fn atoms_normalize_to_single_dims() {
        let nf = normalize(&Dp(8)).unwrap();
        assert_eq!(nf.strategy.dp, 8);
        assert_eq!(nf.strategy.device_count(), 8);
        let nf = normalize(&Sp).unwrap();
        assert!(nf.strategy.sp);
        assert_eq!(nf.strategy.device_count(), 1);
    }

    #[test]
    fn seq_and_nest_share_a_normal_form() {
        let seq = normalize(&Seq(vec![Dp(4), Tp(8), Sp])).unwrap();
        let nest = normalize(&StrategyExpr::nest(Dp(4), Seq(vec![Tp(8), Sp]))).unwrap();
        assert_eq!(seq, nest);
        assert_eq!(seq.strategy.dp, 4);
        assert_eq!(seq.strategy.tp, 8);
        assert!(seq.strategy.sp);
        assert_eq!(seq.strategy.device_count(), 32);
    }

    #[test]
    fn empty_seq_is_the_identity() {
        let nf = normalize(&Seq(vec![])).unwrap();
        assert_eq!(nf.strategy, ParallelStrategy::default());
        // identity law: Seq([e, Seq([])]) == e
        let e = Seq(vec![Tp(8), Pp(2)]);
        let with_id = Seq(vec![e.clone(), Seq(vec![])]);
        assert_eq!(normalize(&e).unwrap(), normalize(&with_id).unwrap());
    }

    #[test]
    fn repeated_dims_multiply() {
        let nf = normalize(&Seq(vec![Dp(2), Dp(3)])).unwrap();
        assert_eq!(nf.strategy.dp, 6);
    }

    #[test]
    fn zero_dims_and_overflow_are_errors_not_panics() {
        assert!(normalize(&Dp(0)).is_err());
        assert!(normalize(&Seq(vec![Tp(4), Cp(0)])).is_err());
        let big = usize::MAX / 2;
        assert!(normalize(&Seq(vec![Dp(big), Dp(3)])).is_err());
        // overflow across dims (total device count) is caught too
        assert!(normalize(&Seq(vec![Dp(big), Tp(3)])).is_err());
    }

    #[test]
    fn pool_constraints_propagate_and_conflict() {
        let nf = normalize(&StrategyExpr::on_pool("910c", Dp(32))).unwrap();
        assert_eq!(nf.pools, vec!["910c".to_string()]);
        let nf = normalize(&StrategyExpr::on_pool("910c, 910b", Dp(64))).unwrap();
        assert_eq!(nf.pools, vec!["910c".to_string(), "910b".to_string()]);
        // same constraint twice is fine
        let same = StrategyExpr::on_pool("910c", StrategyExpr::on_pool("910c", Dp(8)));
        assert!(normalize(&same).is_ok());
        // conflicting constraints are malformed
        let conflict = StrategyExpr::on_pool("910c", StrategyExpr::on_pool("910b", Dp(8)));
        assert!(normalize(&conflict).is_err());
        let split = Seq(vec![
            StrategyExpr::on_pool("910c", Dp(2)),
            StrategyExpr::on_pool("910b", Tp(2)),
        ]);
        assert!(normalize(&split).is_err());
        assert!(normalize(&OnPool(" , ".to_string(), Box::new(Dp(2)))).is_err());
    }

    #[test]
    fn lower_selects_pipeline_schedule_and_grid() {
        let topo = Topology::tiny(); // 8 devices
        let cfg = PlannerConfig::default(); // 16 microbatches
        let plan = lower(&Seq(vec![Dp(2), Tp(2), Pp(2)]), &topo, &cfg).unwrap();
        assert_eq!(plan.grid.tp, 2);
        assert_eq!(plan.grid.dp, 2);
        assert_eq!(plan.grid.pp, 2);
        assert_eq!(plan.schedule, PipelineSchedule::OneFOneB);
        let flat = lower(&Dp(8), &topo, &cfg).unwrap();
        assert_eq!(flat.schedule, PipelineSchedule::Gpipe);
        // non-covering terms error through try_assign_ranks
        assert!(lower(&Dp(3), &topo, &cfg).is_err());
        // pool constraints need a fleet
        let err = lower(&StrategyExpr::on_pool("910c", Dp(8)), &topo, &cfg).unwrap_err();
        assert!(err.contains("lower_fleet"), "err: {err}");
    }

    #[test]
    fn evaluate_expr_matches_hand_built_strategy() {
        let topo = Topology::tiny();
        let cfg = PlannerConfig {
            allow_offload: true,
            ..Default::default()
        };
        let model = ModelDesc::tiny_moe();
        let expr = Seq(vec![Dp(4), Tp(2), Sp]);
        let c = evaluate_expr(&model, &topo, &expr, &cfg).unwrap();
        let s = ParallelStrategy {
            dp: 4,
            tp: 2,
            sp: true,
            ..Default::default()
        };
        let direct = try_evaluate(&model, &topo, &s, &cfg).unwrap();
        assert_eq!(c.step_time.to_bits(), direct.step_time.to_bits());
    }

    #[test]
    fn fleet_lowering_full_fleet_matches_all_devices() {
        let fleet = Fleet::mixed_generations();
        let cfg = PlannerConfig::default();
        let plan = lower_fleet(&Dp(64), &fleet, &cfg).unwrap();
        assert_eq!(plan.group, fleet.all_devices());
        assert_eq!(plan.per_pool, vec![32, 32]);
    }

    #[test]
    fn fleet_lowering_single_pool_matches_pool_devices() {
        let fleet = Fleet::mixed_generations();
        let cfg = PlannerConfig::default();
        let expr = StrategyExpr::on_pool("910b", Dp(32));
        let plan = lower_fleet(&expr, &fleet, &cfg).unwrap();
        assert_eq!(plan.group, fleet.pool_devices(1));
    }

    #[test]
    fn fleet_lowering_prefers_fast_devices() {
        // slow_rack derates rack 0 (ids 0..8); a 24-device term must
        // take ids 8..32, in ascending order
        let fleet = Fleet::slow_rack(0.5);
        let cfg = PlannerConfig::default();
        let plan = lower_fleet(&Dp(24), &fleet, &cfg).unwrap();
        let expected: Vec<DeviceId> = (8..32).map(DeviceId).collect();
        assert_eq!(plan.group, expected);
    }

    #[test]
    fn fleet_lowering_rejects_unknown_pools_and_oversubscription() {
        let fleet = Fleet::mixed_generations();
        let cfg = PlannerConfig::default();
        let unknown = StrategyExpr::on_pool("gb200", Dp(8));
        let err = lower_fleet(&unknown, &fleet, &cfg).unwrap_err();
        assert!(err.contains("910c"), "err should list pools: {err}");
        assert!(lower_fleet(&Dp(65), &fleet, &cfg).is_err());
        let too_big = StrategyExpr::on_pool("910c", Dp(33));
        assert!(lower_fleet(&too_big, &fleet, &cfg).is_err());
        let twice = StrategyExpr::on_pool("910c,910c", Dp(8));
        assert!(lower_fleet(&twice, &fleet, &cfg).is_err());
    }

    #[test]
    fn fleet_sync_time_prices_the_group() {
        let fleet = Fleet::mixed_generations();
        let cfg = PlannerConfig::default();
        let one_pool = StrategyExpr::on_pool("910c", Dp(32));
        let intra = fleet_sync_time(&one_pool, &fleet, &cfg, 1e9).unwrap();
        let cross = fleet_sync_time(&Dp(64), &fleet, &cfg, 1e9).unwrap();
        assert!(intra > 0.0);
        assert!(cross > intra, "cross-pool {cross} vs intra {intra}");
        assert_eq!(fleet_sync_time(&Dp(1), &fleet, &cfg, 1e9).unwrap(), 0.0);
    }

    #[test]
    fn render_and_describe_are_stable() {
        let e = StrategyExpr::on_pool("910c", Seq(vec![Dp(4), Tp(8), Sp]));
        assert_eq!(e.render(), "OnPool(910c, Seq[Dp(4), Tp(8), Sp])");
        let nf = normalize(&e).unwrap();
        assert_eq!(nf.describe(), "dp4 tp8 pp1 ep1 cp1 +sp @910c");
    }
}
