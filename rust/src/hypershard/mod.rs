//! HyperShard (§3.4): declarative parallel programming.
//!
//! - [`layout`] — the `Layout(device_matrix, alias_name, tensor_map)`
//!   abstraction and formal shard-strategy derivation (Fig 6).
//! - [`propagation`] — sharding propagation through ops with automatic
//!   collective insertion (the Fig 5b decoupling).
//! - [`strategies`] — named strategy dimensions per model family
//!   (Table 1).
//! - [`planner`] — topology-aware automatic strategy search (Table 2),
//!   turning "days of manual tuning" into a cost-model sweep.
//! - [`algebra`] — composable strategy expressions (`Seq`/`Nest`/
//!   `OnPool` over the Table 1 atoms) with a normalizer that lowers any
//!   well-formed term to a priced plan (ISSUE 10).
//! - [`autotune`] — generate → prune → parallel-simulate → refine
//!   auto-search over algebra terms under a bounded budget (ISSUE 10).

pub mod algebra;
pub mod autotune;
pub mod heterogeneous;
pub mod layout;
pub mod planner;
pub mod propagation;
pub mod resharding;
pub mod strategies;

pub use algebra::{
    evaluate_expr, fleet_sync_time, lower, lower_fleet, normalize, FleetLoweredPlan,
    LoweredPlan, NormalForm, StrategyExpr,
};
pub use autotune::{
    autotune, AutoTuneConfig, AutoTuneConfigBuilder, ElasticObjective, PlannerObjective,
    StrategyObjective, TuneReport, TunedCandidate,
};
pub use heterogeneous::{
    compute_weights, memory_caps, partition_for_group, proportional_partition,
    try_proportional_partition,
};
pub use layout::{DimSharding, Layout, LayoutError, MapDim, ShardSpec};
pub use planner::{
    assign_ranks, best_plan, evaluate, explain, plan, try_assign_ranks, try_evaluate,
    PlanCandidate, PlannerConfig, PlannerConfigBuilder, RankGrid,
};
pub use propagation::{
    elementwise, matmul, moe_dispatch, reduce, replicated_spec, CommRequirement, Propagated,
};
pub use resharding::{
    actor_weight_sync_time, dp_shard_spec, plan_reshard, reshard_time, reshard_time_fleet,
    ReshardPlan, ReshardStep,
};
pub use strategies::{dimensions_for, template_for, ParallelStrategy};
