//! Parallel strategy auto-search over the algebra (ISSUE 10).
//!
//! The tuner closes the loop the paper's §3.4 promises ("days of
//! manual tuning → automatic search"): an objective describes how to
//! *seed* candidate [`StrategyExpr`] terms, how to *predict* a term's
//! cost cheaply (analytic model), how to *simulate* it faithfully
//! (DES), and how to *mutate* a survivor into neighbors. [`autotune`]
//! then runs generate → prune-by-predicted-cost → parallel-simulate →
//! refine under a bounded simulation budget, fanning every predict and
//! simulate wave across `sim::sweep` workers — bit-identical at any
//! `HP_SWEEP_THREADS` (asserted by `rust/tests/sweep_determinism.rs`).
//!
//! The pruning bound (DESIGN.md "Auto-search"): a candidate is
//! simulated only if `predicted <= round_best_predicted * prune_ratio`.
//! With `prune_ratio >= 1.0` the round's best-*predicted* candidate is
//! never pruned, so when the seed set contains the planner's own
//! lattice (or a hand-written preset term), the tuner's best simulated
//! cost can never exceed that candidate's simulated cost — the
//! "matches or beats every preset" guarantee of
//! `rust/tests/autotune_scenarios.rs`.
//!
//! Two objectives ship here:
//! - [`PlannerObjective`] — homogeneous topology; seeds the exact
//!   divisor lattice `planner::plan` enumerates, predicts with
//!   `try_evaluate`, simulates the pipeline schedule on the DES.
//! - [`ElasticObjective`] — heterogeneous fleet; seeds `OnPool`
//!   placement ladders, predicts speed-sum throughput + fleet
//!   all-reduce, simulates `ElasticTrainJob::step_time_fleet`.

use super::algebra::{lower, lower_fleet, normalize, StrategyExpr};
use super::planner::{try_evaluate, PlannerConfig};
use crate::config::{ModelDesc, ModelFamily};
use crate::sim::parallel_map;
use crate::supernode::{Fleet, Topology};
use crate::trainer::ElasticTrainJob;
use crate::util::summary::SummaryKv;
use std::collections::BTreeSet;

/// Auto-tuner knobs. Build with [`AutoTuneConfig::builder`].
#[derive(Debug, Clone)]
pub struct AutoTuneConfig {
    /// Hard cap on DES simulations across all rounds.
    pub budget: usize,
    /// Prune candidates predicted worse than `round_best * prune_ratio`
    /// before simulating. Must be >= 1.0 so the best-predicted
    /// candidate always survives.
    pub prune_ratio: f64,
    /// Survivors whose neighbors seed the next round.
    pub top_k: usize,
    /// Refinement rounds after the seed round.
    pub refine_rounds: usize,
}

impl Default for AutoTuneConfig {
    fn default() -> Self {
        Self {
            budget: 256,
            prune_ratio: 2.0,
            top_k: 8,
            refine_rounds: 2,
        }
    }
}

impl AutoTuneConfig {
    /// Builder over the defaults (PR 7 `ClusterConfig::builder`
    /// convention).
    pub fn builder() -> AutoTuneConfigBuilder {
        AutoTuneConfigBuilder {
            cfg: Self::default(),
        }
    }
}

/// Builder returned by [`AutoTuneConfig::builder`]; each setter
/// overrides one default, `build` hands the config back.
#[derive(Debug, Clone)]
pub struct AutoTuneConfigBuilder {
    cfg: AutoTuneConfig,
}

impl AutoTuneConfigBuilder {
    pub fn budget(mut self, budget: usize) -> Self {
        self.cfg.budget = budget;
        self
    }

    pub fn prune_ratio(mut self, prune_ratio: f64) -> Self {
        self.cfg.prune_ratio = prune_ratio;
        self
    }

    pub fn top_k(mut self, top_k: usize) -> Self {
        self.cfg.top_k = top_k;
        self
    }

    pub fn refine_rounds(mut self, refine_rounds: usize) -> Self {
        self.cfg.refine_rounds = refine_rounds;
        self
    }

    pub fn build(self) -> AutoTuneConfig {
        assert!(self.cfg.budget >= 1, "autotune budget must be >= 1");
        assert!(
            self.cfg.prune_ratio >= 1.0,
            "prune_ratio < 1.0 would prune the best-predicted candidate"
        );
        self.cfg
    }
}

/// What the tuner searches over: candidate generation, a cheap
/// predicted cost, a faithful simulated cost, and a neighborhood.
/// Costs are seconds (lower is better); infeasible terms are `Err`.
pub trait StrategyObjective: Sync {
    /// Initial candidate terms (round 0).
    fn seed_candidates(&self) -> Vec<StrategyExpr>;
    /// Cheap analytic cost, used for pruning.
    fn predict(&self, expr: &StrategyExpr) -> Result<f64, String>;
    /// Faithful (DES-grounded) cost, used for ranking.
    fn simulate(&self, expr: &StrategyExpr) -> Result<f64, String>;
    /// Local mutations of a surviving term (may return duplicates or
    /// malformed terms; the tuner dedups and drops them).
    fn neighbors(&self, expr: &StrategyExpr) -> Vec<StrategyExpr>;

    /// Canonical label for dedup and deterministic tie-breaks: the
    /// normal form's rendering, or the error text for malformed terms.
    fn label(&self, expr: &StrategyExpr) -> String {
        match normalize(expr) {
            Ok(nf) => nf.describe(),
            Err(e) => format!("malformed: {e}"),
        }
    }
}

/// One scored candidate in a [`TuneReport`].
#[derive(Debug, Clone)]
pub struct TunedCandidate {
    pub expr: StrategyExpr,
    /// Canonical (normal-form) label.
    pub label: String,
    pub predicted: f64,
    pub simulated: f64,
}

/// Result of an [`autotune`] run.
#[derive(Debug, Clone)]
pub struct TuneReport {
    /// All simulated candidates, best (lowest simulated cost) first;
    /// ties break on the label, so ranking is deterministic.
    pub ranked: Vec<TunedCandidate>,
    /// Terms generated across all rounds (before dedup).
    pub generated: usize,
    /// Terms dropped as malformed or infeasible (predict/simulate Err).
    pub infeasible: usize,
    /// Terms dropped by the predicted-cost prune or the budget cap.
    pub pruned: usize,
    /// DES simulations actually run (`<= budget`).
    pub simulated: usize,
    /// Rounds executed (1 seed round + refinements).
    pub rounds: usize,
    /// The configured simulation budget.
    pub budget: usize,
}

impl TuneReport {
    /// The winning candidate, if any survived.
    pub fn best(&self) -> Option<&TunedCandidate> {
        self.ranked.first()
    }
}

impl SummaryKv for TuneReport {
    fn summary_kv(&self) -> Vec<(String, f64)> {
        let within = self.simulated <= self.budget;
        let mut kv = vec![
            ("generated".to_string(), self.generated as f64),
            ("infeasible".to_string(), self.infeasible as f64),
            ("pruned".to_string(), self.pruned as f64),
            ("simulated".to_string(), self.simulated as f64),
            ("rounds".to_string(), self.rounds as f64),
            ("budget_respected".to_string(), if within { 1.0 } else { 0.0 }),
        ];
        if let Some(best) = self.best() {
            kv.push(("best_predicted_s".to_string(), best.predicted));
            kv.push(("best_simulated_s".to_string(), best.simulated));
        }
        kv
    }
}

/// Generate → prune-by-predicted-cost → parallel-simulate → refine,
/// until the budget or the round limit is exhausted. Deterministic
/// for a deterministic objective: every wave is an order-preserving
/// `sim::sweep::parallel_map`, and every sort keys on
/// `(cost.total_cmp, label)`.
fn rank_order(a: &TunedCandidate, b: &TunedCandidate) -> std::cmp::Ordering {
    a.simulated.total_cmp(&b.simulated).then_with(|| a.label.cmp(&b.label))
}

pub fn autotune<O: StrategyObjective>(objective: &O, cfg: &AutoTuneConfig) -> TuneReport {
    let mut report = TuneReport {
        ranked: Vec::new(),
        generated: 0,
        infeasible: 0,
        pruned: 0,
        simulated: 0,
        rounds: 0,
        budget: cfg.budget,
    };
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut candidates = objective.seed_candidates();

    for _round in 0..=cfg.refine_rounds {
        if candidates.is_empty() || report.simulated >= cfg.budget {
            break;
        }
        report.rounds += 1;
        report.generated += candidates.len();

        // dedup by canonical label; malformed terms count infeasible
        let mut fresh: Vec<(StrategyExpr, String)> = Vec::new();
        for expr in candidates.drain(..) {
            let label = objective.label(&expr);
            if label.starts_with("malformed: ") {
                report.infeasible += 1;
                continue;
            }
            if seen.insert(label.clone()) {
                fresh.push((expr, label));
            }
        }
        if fresh.is_empty() {
            break;
        }

        // predict wave (parallel, order-preserving)
        let predictions = parallel_map(&fresh, |(expr, _)| objective.predict(expr));
        let mut scored: Vec<(StrategyExpr, String, f64)> = Vec::new();
        for ((expr, label), pred) in fresh.into_iter().zip(predictions) {
            match pred {
                Ok(p) => scored.push((expr, label, p)),
                Err(_) => report.infeasible += 1,
            }
        }
        if scored.is_empty() {
            break;
        }
        scored.sort_by(|a, b| a.2.total_cmp(&b.2).then_with(|| a.1.cmp(&b.1)));

        // prune: predicted-cost bound, then the remaining budget
        let bound = scored[0].2 * cfg.prune_ratio;
        let before = scored.len();
        scored.retain(|(_, _, p)| *p <= bound);
        report.pruned += before - scored.len();
        let room = cfg.budget - report.simulated;
        if scored.len() > room {
            report.pruned += scored.len() - room;
            scored.truncate(room);
        }

        // simulate wave (parallel, order-preserving)
        let sims = parallel_map(&scored, |(expr, _, _)| objective.simulate(expr));
        report.simulated += scored.len();
        for ((expr, label, predicted), sim) in scored.into_iter().zip(sims) {
            match sim {
                Ok(simulated) => report.ranked.push(TunedCandidate {
                    expr,
                    label,
                    predicted,
                    simulated,
                }),
                Err(_) => report.infeasible += 1,
            }
        }
        report.ranked.sort_by(rank_order);

        // refine: neighbors of the current top-k
        candidates = report
            .ranked
            .iter()
            .take(cfg.top_k)
            .flat_map(|c| objective.neighbors(&c.expr))
            .collect();
    }
    report
}

// ---- planner objective (homogeneous topology) --------------------------

/// Auto-search over a bare topology: the same (dp, tp, pp, ep, cp)
/// lattice `planner::plan` enumerates, expressed as algebra terms, so
/// the tuner's best *predicted* cost equals `plan()`'s best step time
/// bit-for-bit — and the DES simulation then re-ranks the survivors.
pub struct PlannerObjective {
    pub model: ModelDesc,
    pub topo: Topology,
    pub cfg: PlannerConfig,
}

impl PlannerObjective {
    pub fn new(model: ModelDesc, topo: Topology, cfg: PlannerConfig) -> Self {
        Self { model, topo, cfg }
    }

    /// The algebra term for one lattice point, with the family flags
    /// `plan()` would set.
    fn term(&self, dp: usize, tp: usize, pp: usize, ep: usize, cp: usize) -> StrategyExpr {
        let mut parts = vec![
            StrategyExpr::Dp(dp),
            StrategyExpr::Tp(tp),
            StrategyExpr::Pp(pp),
            StrategyExpr::Ep(ep),
            StrategyExpr::Cp(cp),
        ];
        if tp > 1 {
            parts.push(StrategyExpr::Sp);
        }
        if self.model.family == ModelFamily::Diffusion {
            parts.push(StrategyExpr::Fsdp);
        }
        if matches!(self.model.family, ModelFamily::Rl | ModelFamily::OmniModal) {
            parts.push(StrategyExpr::Mpmd);
        }
        StrategyExpr::Seq(parts)
    }
}

fn divisors_up_to(n: usize, cap: usize) -> Vec<usize> {
    (1..=n.min(cap)).filter(|d| n % d == 0).collect()
}

impl StrategyObjective for PlannerObjective {
    fn seed_candidates(&self) -> Vec<StrategyExpr> {
        let n = self.topo.device_count();
        let mut out = Vec::new();
        for tp in divisors_up_to(n, self.cfg.max_tp) {
            for pp in divisors_up_to(n / tp, self.cfg.max_pp.min(self.model.layers)) {
                let rest = n / tp / pp;
                let cps: Vec<usize> = if self.model.family == ModelFamily::LongSequence {
                    divisors_up_to(rest, 16)
                } else {
                    vec![1]
                };
                for cp in cps {
                    let dp = rest / cp;
                    if dp == 0 {
                        continue;
                    }
                    let ep = match self.model.moe {
                        Some(m) => m.experts.min(dp),
                        None => 1,
                    };
                    out.push(self.term(dp, tp, pp, ep, cp));
                }
            }
        }
        out
    }

    fn predict(&self, expr: &StrategyExpr) -> Result<f64, String> {
        let plan = lower(expr, &self.topo, &self.cfg)?;
        let c = try_evaluate(&self.model, &self.topo, &plan.strategy, &self.cfg)?;
        if !c.fits_hbm && !self.cfg.allow_offload {
            return Err(format!(
                "{} does not fit HBM without offload",
                plan.strategy.describe()
            ));
        }
        Ok(c.step_time)
    }

    fn simulate(&self, expr: &StrategyExpr) -> Result<f64, String> {
        let plan = lower(expr, &self.topo, &self.cfg)?;
        let c = try_evaluate(&self.model, &self.topo, &plan.strategy, &self.cfg)?;
        if !c.fits_hbm && !self.cfg.allow_offload {
            return Err(format!(
                "{} does not fit HBM without offload",
                plan.strategy.describe()
            ));
        }
        // Run the selected pipeline schedule on the DES: the per-stage
        // per-microbatch forward time spreads the overlappable work
        // (compute + tp + ep comm) so the zero-bubble total equals the
        // analytic sum, then the schedule's real bubble emerges from
        // the simulation; the dp gradient sync stays a serial tail.
        let m = plan.microbatches.max(1);
        let pp = plan.strategy.pp.max(1);
        let work = c.compute_time + c.tp_comm_time + c.ep_comm_time;
        let fwd = work / (3.0 * m as f64 * pp as f64);
        let rep = plan.schedule.simulate(&vec![fwd; pp], m);
        Ok(rep.makespan + c.dp_comm_time)
    }

    fn neighbors(&self, expr: &StrategyExpr) -> Vec<StrategyExpr> {
        let Ok(nf) = normalize(expr) else {
            return Vec::new();
        };
        let s = nf.strategy;
        let n = self.topo.device_count();
        let mut out = Vec::new();
        // halve/double tp and pp along the divisor lattice, rebalancing
        // dp so the term still covers the cluster
        for (tp, pp) in [
            (s.tp * 2, s.pp),
            (s.tp / 2, s.pp),
            (s.tp, s.pp * 2),
            (s.tp, s.pp / 2),
        ] {
            if tp == 0 || pp == 0 || tp > self.cfg.max_tp || pp > self.cfg.max_pp {
                continue;
            }
            let denom = tp * pp * s.cp;
            if denom == 0 || n % denom != 0 {
                continue;
            }
            let dp = n / denom;
            let ep = match self.model.moe {
                Some(m) => m.experts.min(dp),
                None => 1,
            };
            out.push(self.term(dp, tp, pp, ep, s.cp));
        }
        out
    }
}

// ---- elastic fleet objective (heterogeneous placement) -----------------

/// Auto-search of an [`ElasticTrainJob`]'s lease over a heterogeneous
/// fleet: candidates are `OnPool` placement ladders (`Dp(n)` on each
/// pool, and across the whole fleet), predicted by speed-sum
/// throughput plus the fleet gradient all-reduce, simulated by
/// `step_time_fleet` — so a candidate spanning exactly a preset's
/// device group simulates to the preset's cost bit-for-bit.
pub struct ElasticObjective {
    pub job: ElasticTrainJob,
    pub fleet: Fleet,
    /// Heterogeneity-aware compute plan (`true` for HyperParallel).
    pub aware: bool,
    pub cfg: PlannerConfig,
}

impl ElasticObjective {
    pub fn new(job: ElasticTrainJob, fleet: Fleet, aware: bool) -> Self {
        Self {
            job,
            fleet,
            aware,
            cfg: PlannerConfig::default(),
        }
    }

    /// Serial compute work of one step (seconds on one reference
    /// device).
    fn total_work(&self) -> f64 {
        let per_mb: f64 = self
            .job
            .workload
            .modules
            .iter()
            .map(|m| m.time_per_microbatch)
            .sum();
        per_mb * self.job.workload.microbatches as f64
    }

    /// Device capacity of a placement pattern (`None` = whole fleet).
    fn capacity(&self, pools: &[String]) -> usize {
        if pools.is_empty() {
            return self.fleet.device_count();
        }
        self.fleet
            .pools
            .iter()
            .filter(|p| pools.contains(&p.name))
            .map(|p| p.topo.device_count())
            .sum()
    }

    fn wrap(&self, pools: &[String], dp: usize) -> StrategyExpr {
        let atom = StrategyExpr::Dp(dp);
        if pools.is_empty() {
            atom
        } else {
            StrategyExpr::on_pool(&pools.join(","), atom)
        }
    }
}

impl StrategyObjective for ElasticObjective {
    fn seed_candidates(&self) -> Vec<StrategyExpr> {
        // placement patterns: each pool alone, plus the whole fleet
        let mut patterns: Vec<Vec<String>> = self
            .fleet
            .pools
            .iter()
            .map(|p| vec![p.name.clone()])
            .collect();
        if self.fleet.pool_count() > 1 {
            patterns.push(Vec::new());
        }
        let mut out = Vec::new();
        for pools in &patterns {
            let cap = self.capacity(pools);
            let mut sizes: Vec<usize> = Vec::new();
            let mut p = 1;
            while p < cap {
                sizes.push(p);
                p *= 2;
            }
            sizes.push(cap);
            for dp in sizes {
                out.push(self.wrap(pools, dp));
            }
        }
        out
    }

    fn predict(&self, expr: &StrategyExpr) -> Result<f64, String> {
        let plan = lower_fleet(expr, &self.fleet, &self.cfg)?;
        let speeds = self.fleet.speeds(&plan.group);
        let throughput: f64 = speeds.iter().sum();
        if throughput <= 0.0 {
            return Err("placement has zero aggregate throughput".to_string());
        }
        let compute = self.total_work() / throughput;
        let sync = if plan.group.len() > 1 {
            self.job.sync_time_fleet(&self.fleet, &plan.group)
        } else {
            0.0
        };
        Ok(compute + sync)
    }

    fn simulate(&self, expr: &StrategyExpr) -> Result<f64, String> {
        let plan = lower_fleet(expr, &self.fleet, &self.cfg)?;
        Ok(self
            .job
            .step_time_fleet(&self.fleet, &plan.group, self.aware))
    }

    fn neighbors(&self, expr: &StrategyExpr) -> Vec<StrategyExpr> {
        let Ok(nf) = normalize(expr) else {
            return Vec::new();
        };
        let cap = self.capacity(&nf.pools);
        let dp = nf.strategy.dp as i64;
        let mut out = Vec::new();
        for delta in [-4i64, -2, -1, 1, 2, 4] {
            let next = dp + delta;
            if (1..=cap as i64).contains(&next) && next != dp {
                out.push(self.wrap(&nf.pools, next as usize));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypershard::planner::plan;

    fn offload_cfg() -> PlannerConfig {
        PlannerConfig {
            allow_offload: true,
            ..Default::default()
        }
    }

    #[test]
    fn builder_overrides_defaults() {
        let cfg = AutoTuneConfig::builder()
            .budget(64)
            .prune_ratio(1.5)
            .top_k(4)
            .refine_rounds(1)
            .build();
        assert_eq!(cfg.budget, 64);
        assert_eq!(cfg.prune_ratio, 1.5);
        assert_eq!(cfg.top_k, 4);
        assert_eq!(cfg.refine_rounds, 1);
    }

    #[test]
    #[should_panic(expected = "prune_ratio")]
    fn builder_rejects_pruning_the_best() {
        let _ = AutoTuneConfig::builder().prune_ratio(0.5).build();
    }

    #[test]
    fn planner_objective_best_prediction_matches_plan() {
        let model = ModelDesc::tiny_moe();
        let topo = Topology::tiny();
        let obj = PlannerObjective::new(model.clone(), topo.clone(), offload_cfg());
        let report = autotune(&obj, &AutoTuneConfig::default());
        // min over *all* lattice candidates, not plan()[0]: the planner
        // sorts fits-HBM first, the tuner ranks purely by cost
        let planned = plan(&model, &topo, &offload_cfg());
        let best_planned = planned.iter().map(|c| c.step_time).fold(f64::INFINITY, f64::min);
        let best_predicted = report
            .ranked
            .iter()
            .map(|c| c.predicted)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(
            best_predicted.to_bits(),
            best_planned.to_bits(),
            "tuner {best_predicted} vs plan {best_planned}"
        );
        assert!(report.simulated <= report.budget);
        assert!(report.best().is_some());
    }

    #[test]
    fn tuner_respects_a_tiny_budget() {
        let obj = PlannerObjective::new(ModelDesc::tiny_moe(), Topology::tiny(), offload_cfg());
        let cfg = AutoTuneConfig::builder().budget(3).build();
        let report = autotune(&obj, &cfg);
        assert!(report.simulated <= 3, "simulated {}", report.simulated);
        assert!(report.pruned > 0 || report.infeasible > 0 || report.generated <= 3);
    }

    #[test]
    fn elastic_objective_prefers_fast_silicon() {
        let fleet = Fleet::slow_rack(0.5);
        let job = crate::hypermpmd::cosched_train_job();
        let obj = ElasticObjective::new(job, fleet.clone(), true);
        let report = autotune(&obj, &AutoTuneConfig::default());
        let best = report.best().expect("some candidate survives");
        // the full 32-device lease (8 of them derated) must not beat
        // the tuner's best: skipping or shrinking around the slow rack
        // is at least as good
        let full = lower_fleet(&StrategyExpr::Dp(32), &fleet, &PlannerConfig::default()).unwrap();
        let full_cost = obj.job.step_time_fleet(&fleet, &full.group, true);
        assert!(
            best.simulated <= full_cost * (1.0 + 1e-12),
            "best {} vs full lease {}",
            best.simulated,
            full_cost
        );
    }

    #[test]
    fn report_summary_kv_has_the_ledger() {
        let obj = PlannerObjective::new(ModelDesc::tiny_moe(), Topology::tiny(), offload_cfg());
        let report = autotune(&obj, &AutoTuneConfig::default());
        let kv = report.summary_kv();
        let get = |k: &str| {
            kv.iter()
                .find(|(key, _)| key == k)
                .map(|(_, v)| *v)
                .unwrap()
        };
        assert_eq!(get("simulated"), report.simulated as f64);
        assert_eq!(get("budget_respected"), 1.0);
        assert!(get("best_simulated_s") > 0.0);
    }
}
