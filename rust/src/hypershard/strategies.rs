//! Named parallel strategies and the model→strategy mapping of the
//! paper's Table 1.

use crate::config::ModelFamily;

/// A concrete multi-dimensional parallel strategy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParallelStrategy {
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub ep: usize,
    pub cp: usize,
    /// Sequence parallelism piggybacks on the TP group.
    pub sp: bool,
    /// ZeRO-3-style fully sharded data parallelism.
    pub fsdp: bool,
    /// MPMD (task-level) parallelism — the RL row of Table 1.
    pub mpmd: bool,
}

impl Default for ParallelStrategy {
    fn default() -> Self {
        Self {
            dp: 1,
            tp: 1,
            pp: 1,
            ep: 1,
            cp: 1,
            sp: false,
            fsdp: false,
            mpmd: false,
        }
    }
}

impl ParallelStrategy {
    pub fn device_count(&self) -> usize {
        // EP reuses the DP×(CP) dimension for expert placement in this
        // framework (DeepSeek-style), so it does not multiply.
        self.dp * self.tp * self.pp * self.cp
    }

    /// Names of the dimensions in use (for Table 1 rendering).
    pub fn dims_used(&self) -> Vec<&'static str> {
        let mut v = Vec::new();
        if self.dp > 1 {
            v.push("DP");
        }
        if self.pp > 1 {
            v.push("PP");
        }
        if self.tp > 1 {
            v.push("TP");
        }
        if self.sp {
            v.push("SP");
        }
        if self.ep > 1 {
            v.push("EP");
        }
        if self.cp > 1 {
            v.push("CP");
        }
        if self.fsdp {
            v.push("FSDP");
        }
        if self.mpmd {
            v.push("MPMD");
        }
        v
    }

    pub fn describe(&self) -> String {
        format!(
            "dp{} tp{} pp{} ep{} cp{}{}{}{}",
            self.dp,
            self.tp,
            self.pp,
            self.ep,
            self.cp,
            if self.sp { " +sp" } else { "" },
            if self.fsdp { " +fsdp" } else { "" },
            if self.mpmd { " +mpmd" } else { "" },
        )
    }
}

/// The *dimensions* each model family needs — the paper's Table 1.
/// (The planner later chooses concrete sizes per cluster — Table 2.)
pub fn dimensions_for(family: ModelFamily) -> Vec<&'static str> {
    match family {
        ModelFamily::DenseTransformer => vec!["DP", "PP", "TP", "SP"],
        ModelFamily::SparseMoe => vec!["DP", "PP", "TP", "SP", "EP"],
        ModelFamily::Diffusion => vec!["DP", "FSDP"],
        ModelFamily::LongSequence => vec!["SP", "CP"],
        ModelFamily::Rl => vec!["MPMD"],
        ModelFamily::OmniModal => vec!["DP", "PP", "TP", "MPMD"],
    }
}

/// Seed strategy template for a family (sizes filled by the planner).
pub fn template_for(family: ModelFamily) -> ParallelStrategy {
    match family {
        ModelFamily::DenseTransformer => ParallelStrategy {
            sp: true,
            ..Default::default()
        },
        ModelFamily::SparseMoe => ParallelStrategy {
            sp: true,
            ep: 2, // placeholder >1 so EP is considered
            ..Default::default()
        },
        ModelFamily::Diffusion => ParallelStrategy {
            fsdp: true,
            ..Default::default()
        },
        ModelFamily::LongSequence => ParallelStrategy {
            sp: true,
            cp: 2,
            ..Default::default()
        },
        ModelFamily::Rl => ParallelStrategy {
            mpmd: true,
            ..Default::default()
        },
        ModelFamily::OmniModal => ParallelStrategy {
            mpmd: true,
            sp: true,
            ..Default::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows() {
        assert_eq!(
            dimensions_for(ModelFamily::DenseTransformer),
            vec!["DP", "PP", "TP", "SP"]
        );
        assert_eq!(
            dimensions_for(ModelFamily::SparseMoe),
            vec!["DP", "PP", "TP", "SP", "EP"]
        );
        assert_eq!(dimensions_for(ModelFamily::Diffusion), vec!["DP", "FSDP"]);
        assert_eq!(dimensions_for(ModelFamily::LongSequence), vec!["SP", "CP"]);
        assert_eq!(dimensions_for(ModelFamily::Rl), vec!["MPMD"]);
    }

    #[test]
    fn device_count_multiplies() {
        let s = ParallelStrategy {
            dp: 4,
            tp: 8,
            pp: 2,
            ..Default::default()
        };
        assert_eq!(s.device_count(), 64);
    }

    #[test]
    fn dims_used_reflects_sizes() {
        let s = ParallelStrategy {
            dp: 2,
            tp: 8,
            sp: true,
            ..Default::default()
        };
        assert_eq!(s.dims_used(), vec!["DP", "TP", "SP"]);
    }
}
