//! Compute-proportional partitioning for heterogeneous fleets.
//!
//! H2 (PAPERS.md) shows that on mixed-generation fleets the win is in
//! sizing each device's share of the model by its *roofline*, not by
//! headcount: a 910B next to a 910C should hold roughly half the
//! layers/experts, or it stalls every synchronous step. This module
//! turns a fleet-global device group into integer partition sizes:
//!
//! - [`compute_weights`] — per-device throughput shares.
//! - [`proportional_partition`] — largest-remainder apportionment of
//!   `total` indivisible items (layers, experts) over those weights,
//!   with optional per-device capacity caps (HBM).
//! - [`memory_caps`] — caps derived from each device's HBM spec.
//!
//! Everything is deterministic: ties break on the lowest device index,
//! and a uniform group always yields the same sizes as count-based
//! splitting (`total / n` each, remainder to the lowest indices) — the
//! degenerate case changes nothing.

use crate::supernode::{DeviceId, Fleet};

/// Per-device compute weight over a fleet-global group: cube FLOPs,
/// normalized so the weights sum to 1.
pub fn compute_weights(fleet: &Fleet, group: &[DeviceId]) -> Vec<f64> {
    let raw: Vec<f64> = group.iter().map(|&d| fleet.spec(d).cube_flops).collect();
    let sum: f64 = raw.iter().sum();
    raw.iter().map(|w| w / sum).collect()
}

/// Per-device item caps from HBM capacity: how many `bytes_per_item`
/// items (layers, expert shards) fit in each device's HBM.
pub fn memory_caps(fleet: &Fleet, group: &[DeviceId], bytes_per_item: f64) -> Vec<usize> {
    group
        .iter()
        .map(|&d| (fleet.spec(d).hbm_bytes as f64 / bytes_per_item).floor() as usize)
        .collect()
}

/// Apportion `total` indivisible items over `weights` by the largest-
/// remainder method, honoring optional per-slot `caps`.
///
/// Invariants (property-tested):
/// - the returned sizes sum to exactly `total`;
/// - no slot exceeds its cap;
/// - uniform weights reproduce count-based splitting (`total / n`
///   plus remainder to the lowest indices).
///
/// Panics if the caps cannot hold `total` items at all. Strategy-
/// algebra lowering, which must turn infeasibility into `Err` rather
/// than a panic, goes through [`try_proportional_partition`].
pub fn proportional_partition(total: usize, weights: &[f64], caps: Option<&[usize]>) -> Vec<usize> {
    match try_proportional_partition(total, weights, caps) {
        Ok(sizes) => sizes,
        Err(e) => panic!("{e}"),
    }
}

/// Non-panicking [`proportional_partition`]: empty groups, mismatched
/// cap lengths, and infeasible caps come back as `Err` (ISSUE 10 —
/// malformed strategy expressions must not panic the normalizer).
pub fn try_proportional_partition(
    total: usize,
    weights: &[f64],
    caps: Option<&[usize]>,
) -> Result<Vec<usize>, String> {
    let n = weights.len();
    if n == 0 {
        return Err("cannot partition over an empty group".to_string());
    }
    if let Some(c) = caps {
        if c.len() != n {
            return Err(format!(
                "caps length {} must match weights length {n}",
                c.len()
            ));
        }
        if c.iter().sum::<usize>() < total {
            return Err(format!("memory caps cannot hold {total} items"));
        }
    }
    let wsum: f64 = weights.iter().sum();
    let cap_of = |i: usize| caps.map_or(usize::MAX, |c| c[i]);

    // integer floors of the exact quotas, clamped to caps
    let quotas: Vec<f64> = weights.iter().map(|w| total as f64 * w / wsum).collect();
    let mut sizes: Vec<usize> = quotas
        .iter()
        .enumerate()
        .map(|(i, q)| (q.floor() as usize).min(cap_of(i)))
        .collect();

    // hand out the remainder by largest fractional part (ties: lowest
    // index), skipping slots at their cap; repeat passes until placed
    // (a pass can stall only when every slot capped out, which the
    // feasibility assert above excludes).
    let mut rest = total - sizes.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    while rest > 0 {
        let mut placed = false;
        for &i in &order {
            if rest == 0 {
                break;
            }
            if sizes[i] < cap_of(i) {
                sizes[i] += 1;
                rest -= 1;
                placed = true;
            }
        }
        if !placed {
            return Err(format!("memory caps cannot hold {total} items"));
        }
    }
    Ok(sizes)
}

/// Convenience: compute-proportional sizes for a fleet group with HBM
/// caps at `bytes_per_item` per item.
pub fn partition_for_group(
    fleet: &Fleet,
    group: &[DeviceId],
    total: usize,
    bytes_per_item: f64,
) -> Vec<usize> {
    let weights = compute_weights(fleet, group);
    let caps = memory_caps(fleet, group, bytes_per_item);
    proportional_partition(total, &weights, Some(&caps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::supernode::Topology;

    #[test]
    fn uniform_weights_reproduce_count_split() {
        let sizes = proportional_partition(10, &[1.0, 1.0, 1.0], None);
        assert_eq!(sizes, vec![4, 3, 3]);
        let sizes = proportional_partition(12, &[1.0; 4], None);
        assert_eq!(sizes, vec![3, 3, 3, 3]);
    }

    #[test]
    fn proportional_split_follows_weights() {
        // 2:1 compute → 2:1 layers
        let sizes = proportional_partition(9, &[2.0, 1.0], None);
        assert_eq!(sizes, vec![6, 3]);
    }

    #[test]
    fn caps_redirect_overflow() {
        // the fast slot can only hold 4; the rest spills over
        let sizes = proportional_partition(9, &[2.0, 1.0], Some(&[4, 9]));
        assert_eq!(sizes.iter().sum::<usize>(), 9);
        assert_eq!(sizes[0], 4);
        assert_eq!(sizes[1], 5);
    }

    #[test]
    #[should_panic(expected = "memory caps cannot hold")]
    fn infeasible_caps_panic() {
        proportional_partition(10, &[1.0, 1.0], Some(&[4, 4]));
    }

    #[test]
    fn try_variant_errors_instead_of_panicking() {
        assert!(try_proportional_partition(10, &[1.0, 1.0], Some(&[4, 4])).is_err());
        assert!(try_proportional_partition(3, &[], None).is_err());
        assert!(try_proportional_partition(3, &[1.0, 1.0], Some(&[3])).is_err());
        let ok = try_proportional_partition(9, &[2.0, 1.0], None).unwrap();
        assert_eq!(ok, proportional_partition(9, &[2.0, 1.0], None));
    }

    #[test]
    fn mixed_generation_group_is_roofline_proportional() {
        let fleet = Fleet::mixed_generations();
        let group = fleet.all_devices();
        let w = compute_weights(&fleet, &group);
        // 910C weight / 910B weight = 350/176
        assert!((w[0] / w[32] - 350.0 / 176.0).abs() < 1e-9);
        let sizes = partition_for_group(&fleet, &group, 256, 512e6);
        assert_eq!(sizes.iter().sum::<usize>(), 256);
        assert!(sizes[0] > sizes[32], "910C should hold more: {sizes:?}");
    }

    #[test]
    fn single_pool_fleet_partitions_like_counts() {
        let fleet = Fleet::single(Topology::tiny());
        let group = fleet.all_devices();
        let sizes = partition_for_group(&fleet, &group, 17, 1e9);
        // uniform specs → count-based split, remainder to low indices
        assert_eq!(sizes, vec![3, 2, 2, 2, 2, 2, 2, 2]);
    }
}
