//! Topology-aware automatic strategy planner.
//!
//! Reproduces the paper's Table 2: given a model and a cluster, search
//! the (dp, tp, pp, ep, cp) space with an analytic step-time cost model
//! whose communication terms come from `collectives::cost` over the
//! *actual* topology — so the same model gets TP8+PP on an 8-die
//! machine, high-dimension TP16 on a 16-die supernode board pair, and
//! topology-aware TP16 with reduced PP on an 8k hyperplane, exactly the
//! paper's rows. The paper's "days → hours" tuning claim becomes
//! "milliseconds" here because the search is a cost-model sweep instead
//! of live cluster runs; `bench_hypershard` measures it.

use super::strategies::ParallelStrategy;
use crate::collectives;
use crate::config::{ModelDesc, ModelFamily};
use crate::graph::CollectiveKind;
use crate::supernode::{DeviceId, Topology};

/// A scored strategy candidate.
#[derive(Debug, Clone)]
pub struct PlanCandidate {
    pub strategy: ParallelStrategy,
    /// Estimated step time, seconds.
    pub step_time: f64,
    /// Component breakdown for the explain output.
    pub compute_time: f64,
    pub tp_comm_time: f64,
    pub dp_comm_time: f64,
    pub ep_comm_time: f64,
    pub pp_bubble_time: f64,
    /// Per-device state bytes (weights+grads+optimizer after sharding).
    pub state_bytes_per_device: u64,
    /// Whether the state fits HBM without offloading.
    pub fits_hbm: bool,
}

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Achievable cube efficiency (MFU-style derating).
    pub cube_efficiency: f64,
    /// Microbatches per global batch for pipeline schedules.
    pub microbatches: usize,
    /// Allow strategies whose state exceeds HBM (requires HyperOffload).
    pub allow_offload: bool,
    /// Max TP degree to consider.
    pub max_tp: usize,
    /// Max PP degree to consider.
    pub max_pp: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            cube_efficiency: 0.45,
            microbatches: 16,
            allow_offload: false,
            max_tp: 32,
            max_pp: 64,
        }
    }
}

impl PlannerConfig {
    /// Builder over the defaults (PR 7 `ClusterConfig::builder`
    /// convention).
    pub fn builder() -> PlannerConfigBuilder {
        PlannerConfigBuilder {
            cfg: Self::default(),
        }
    }
}

/// Builder returned by [`PlannerConfig::builder`]; each setter
/// overrides one default, `build` hands the config back.
#[derive(Debug, Clone)]
pub struct PlannerConfigBuilder {
    cfg: PlannerConfig,
}

impl PlannerConfigBuilder {
    pub fn cube_efficiency(mut self, cube_efficiency: f64) -> Self {
        self.cfg.cube_efficiency = cube_efficiency;
        self
    }

    pub fn microbatches(mut self, microbatches: usize) -> Self {
        self.cfg.microbatches = microbatches;
        self
    }

    pub fn allow_offload(mut self, allow_offload: bool) -> Self {
        self.cfg.allow_offload = allow_offload;
        self
    }

    pub fn max_tp(mut self, max_tp: usize) -> Self {
        self.cfg.max_tp = max_tp;
        self
    }

    pub fn max_pp(mut self, max_pp: usize) -> Self {
        self.cfg.max_pp = max_pp;
        self
    }

    pub fn build(self) -> PlannerConfig {
        assert!(
            self.cfg.cube_efficiency > 0.0 && self.cfg.cube_efficiency <= 1.0,
            "cube_efficiency must be in (0, 1]"
        );
        assert!(self.cfg.microbatches >= 1, "need at least one microbatch");
        self.cfg
    }
}

/// Assign devices to a (pp, dp, tp) grid with TP innermost so TP groups
/// are contiguous ranks — i.e. land within a board whenever tp ≤
/// dies_per_board. This *is* the topology awareness: the same strategy
/// costed with scattered TP groups would be far slower.
///
/// Panics on a strategy that does not cover `n` devices; use
/// [`try_assign_ranks`] to handle untrusted strategies.
pub fn assign_ranks(strategy: &ParallelStrategy, n: usize) -> RankGrid {
    try_assign_ranks(strategy, n).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible form of [`assign_ranks`]: errors (instead of panicking)
/// when `tp·dp·pp·cp` does not exactly cover the `n` available
/// devices — the guard that keeps a hand-built strategy from indexing
/// past the device table deeper in the cost model.
pub fn try_assign_ranks(strategy: &ParallelStrategy, n: usize) -> Result<RankGrid, String> {
    let tp = strategy.tp;
    let dp = strategy.dp;
    let pp = strategy.pp;
    let cp = strategy.cp;
    let covered = tp * dp * pp * cp;
    if covered != n {
        return Err(format!(
            "strategy covers {covered} devices (tp {tp} x dp {dp} x pp {pp} x cp {cp}) \
             but the cluster has {n}"
        ));
    }
    Ok(RankGrid { tp, dp, pp, cp })
}

/// Rank bookkeeping for a 4D (pp, dp, cp, tp) grid, tp innermost.
#[derive(Debug, Clone, Copy)]
pub struct RankGrid {
    pub tp: usize,
    pub dp: usize,
    pub pp: usize,
    pub cp: usize,
}

impl RankGrid {
    /// The TP group containing rank 0 of a given (pp, dp, cp) slice.
    pub fn tp_group(&self, pp_idx: usize, dp_idx: usize, cp_idx: usize) -> Vec<DeviceId> {
        let base = ((pp_idx * self.dp + dp_idx) * self.cp + cp_idx) * self.tp;
        (0..self.tp).map(|i| DeviceId(base + i)).collect()
    }

    /// The DP group of tp-rank 0 in pipeline stage `pp_idx`: strided by
    /// cp·tp.
    pub fn dp_group(&self, pp_idx: usize) -> Vec<DeviceId> {
        let stride = self.cp * self.tp;
        let base = pp_idx * self.dp * stride;
        (0..self.dp).map(|i| DeviceId(base + i * stride)).collect()
    }

    /// EP group: experts are spread over the DP dimension
    /// (DeepSeek-style EP ⊆ DP), clamped to `ep` members.
    pub fn ep_group(&self, ep: usize) -> Vec<DeviceId> {
        let stride = self.cp * self.tp;
        (0..ep.min(self.dp)).map(|i| DeviceId(i * stride)).collect()
    }
}

fn divisors_up_to(n: usize, cap: usize) -> Vec<usize> {
    (1..=n.min(cap)).filter(|d| n % d == 0).collect()
}

/// Cost one concrete strategy. Panics on a strategy that needs more
/// devices than the topology has; use [`try_evaluate`] for untrusted
/// strategies.
pub fn evaluate(
    model: &ModelDesc,
    topo: &Topology,
    strategy: &ParallelStrategy,
    cfg: &PlannerConfig,
) -> PlanCandidate {
    try_evaluate(model, topo, strategy, cfg).unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible form of [`evaluate`]: errors when the strategy's device
/// count exceeds the topology (the old behavior indexed past the
/// device table inside `collectives::cost` and panicked there) or
/// does not exactly cover the cluster (the invariant `plan()`'s
/// enumeration maintains and `plans_cover_cluster_exactly` asserts).
pub fn try_evaluate(
    model: &ModelDesc,
    topo: &Topology,
    strategy: &ParallelStrategy,
    cfg: &PlannerConfig,
) -> Result<PlanCandidate, String> {
    let n = strategy.device_count();
    let available = topo.device_count();
    if n > available {
        return Err(format!(
            "strategy needs {n} devices but the topology has only {available}"
        ));
    }
    let grid = try_assign_ranks(strategy, available)?;
    let spec = &topo.devices[0].spec;

    // --- compute: model FLOPs split over all devices --------------------
    let flops_per_device = model.train_flops_per_step() / n as f64;
    let compute_time = flops_per_device / (spec.cube_flops * cfg.cube_efficiency);

    // --- TP communication -------------------------------------------------
    // Megatron: 4 all-reduces per layer per microbatch (2 fwd, 2 bwd) of
    // activation size batch·seq·hidden / (dp·cp·microbatches).
    let tp_comm_time = if strategy.tp > 1 {
        let group = grid.tp_group(0, 0, 0);
        let act_bytes = (model.batch * model.seq) as f64 * model.hidden as f64 * 2.0
            / (strategy.dp * strategy.cp) as f64
            / cfg.microbatches as f64;
        let per = collectives::cost(topo, CollectiveKind::AllReduce, act_bytes, &group).time;
        per * 4.0 * model.layers as f64 * cfg.microbatches as f64
    } else {
        0.0
    };

    // --- DP gradient all-reduce -----------------------------------------
    let dp_comm_time = if strategy.dp > 1 {
        let group = grid.dp_group(0);
        let grad_bytes = model.params() as f64 * 2.0 / (strategy.tp * strategy.pp) as f64;
        collectives::cost(topo, CollectiveKind::AllReduce, grad_bytes, &group).time
    } else {
        0.0
    };

    // --- EP all-to-all (MoE dispatch + combine per layer) ----------------
    let ep_comm_time = if strategy.ep > 1 && model.moe.is_some() {
        let group = grid.ep_group(strategy.ep);
        let bytes = model.moe_dispatch_bytes() / (strategy.dp * strategy.cp) as f64;
        let per = collectives::cost(topo, CollectiveKind::AllToAll, bytes, &group).time;
        per * 2.0 * model.layers as f64
    } else {
        0.0
    };

    // --- PP bubble --------------------------------------------------------
    // 1F1B: bubble fraction = (pp−1)/(m + pp − 1) of the compute time.
    let pp_bubble_time = if strategy.pp > 1 {
        let m = cfg.microbatches as f64;
        let p = strategy.pp as f64;
        compute_time * (p - 1.0) / (m + p - 1.0) * (m + p - 1.0) / m
    } else {
        0.0
    };

    // --- memory -----------------------------------------------------------
    let state = model.train_state();
    let persistent = state.weights + state.gradients + state.optimizer;
    // weights/grads/optimizer shard over tp·pp (and ep for expert params)
    let ep_shard = if model.moe.is_some() {
        strategy.ep.max(1) as u64
    } else {
        1
    };
    let expert_frac = model.expert_param_frac();
    let dense_bytes = (persistent as f64 * (1.0 - expert_frac)) as u64
        / (strategy.tp * strategy.pp) as u64;
    let expert_bytes =
        (persistent as f64 * expert_frac) as u64 / (strategy.tp * strategy.pp) as u64 / ep_shard;
    let act_bytes = state.activations / (strategy.dp * strategy.tp * strategy.pp * strategy.cp) as u64;
    let state_bytes_per_device = dense_bytes + expert_bytes + act_bytes;
    let fits_hbm = state_bytes_per_device <= spec.hbm_bytes;

    let step_time = compute_time + tp_comm_time + dp_comm_time + ep_comm_time + pp_bubble_time;
    Ok(PlanCandidate {
        strategy: strategy.clone(),
        step_time,
        compute_time,
        tp_comm_time,
        dp_comm_time,
        ep_comm_time,
        pp_bubble_time,
        state_bytes_per_device,
        fits_hbm,
    })
}

/// Search all feasible strategies for `model` on `topo`; return
/// candidates sorted by step time (feasible-in-HBM first unless
/// `allow_offload`).
pub fn plan(model: &ModelDesc, topo: &Topology, cfg: &PlannerConfig) -> Vec<PlanCandidate> {
    let n = topo.device_count();
    let mut out = Vec::new();
    for tp in divisors_up_to(n, cfg.max_tp) {
        // TP groups must not straddle the slowest tier on legacy
        // fabrics; the cost model penalizes it anyway, so enumerate all.
        for pp in divisors_up_to(n / tp, cfg.max_pp.min(model.layers)) {
            let rest = n / tp / pp;
            // CP only for long-sequence family
            let cps: Vec<usize> = if model.family == ModelFamily::LongSequence {
                divisors_up_to(rest, 16)
            } else {
                vec![1]
            };
            for cp in cps {
                let dp = rest / cp;
                if dp == 0 {
                    continue;
                }
                let ep = match model.moe {
                    Some(m) => m.experts.min(dp),
                    None => 1,
                };
                let strategy = ParallelStrategy {
                    dp,
                    tp,
                    pp,
                    ep,
                    cp,
                    sp: tp > 1,
                    fsdp: model.family == ModelFamily::Diffusion,
                    mpmd: matches!(model.family, ModelFamily::Rl | ModelFamily::OmniModal),
                };
                // enumeration only emits covering strategies, but stay
                // on the checked path: a malformed one is skipped, not
                // a panic deep inside the cost model
                let Ok(cand) = try_evaluate(model, topo, &strategy, cfg) else {
                    continue;
                };
                if cand.fits_hbm || cfg.allow_offload {
                    out.push(cand);
                }
            }
        }
    }
    out.sort_by(|a, b| {
        (!a.fits_hbm)
            .cmp(&!b.fits_hbm)
            .then(a.step_time.partial_cmp(&b.step_time).unwrap())
    });
    out
}

/// The best plan, if any strategy is feasible.
pub fn best_plan(
    model: &ModelDesc,
    topo: &Topology,
    cfg: &PlannerConfig,
) -> Option<PlanCandidate> {
    plan(model, topo, cfg).into_iter().next()
}

/// Render a plan explanation (the declarative-programming UX of §3.4).
pub fn explain(c: &PlanCandidate) -> String {
    format!(
        "{}: step {:.3}s = compute {:.3}s + tp {:.3}s + dp {:.3}s + ep {:.3}s + bubble {:.3}s; \
         state/device {}, fits HBM: {}",
        c.strategy.describe(),
        c.step_time,
        c.compute_time,
        c.tp_comm_time,
        c.dp_comm_time,
        c.ep_comm_time,
        c.pp_bubble_time,
        crate::util::stats::fmt_bytes(c.state_bytes_per_device),
        c.fits_hbm,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::supernode::{DeviceSpec, Fabric, Geometry};

    fn cfg_offload() -> PlannerConfig {
        PlannerConfig {
            allow_offload: true,
            ..Default::default()
        }
    }

    /// Table 2 row 1: a single 8-die machine → TP8 (+PP for the rest).
    /// The 30B model's state forces tp·pp = 8; intra-board TP is cheap
    /// on the supernode, so TP8 beats TP4·PP2's bubbles.
    #[test]
    fn single_machine_8die_prefers_tp8() {
        let topo = Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 1,
                dies_per_board: 8,
            },
            Fabric::supernode(),
            DeviceSpec::ascend_910c(),
        );
        let best = best_plan(&ModelDesc::dense_30b(), &topo, &cfg_offload()).unwrap();
        assert_eq!(best.strategy.tp, 8, "best={}", explain(&best));
    }

    /// Table 2 row 2: a 16-die supernode machine → high-dimension TP16,
    /// reduced PP (the 50B model forces tp·pp = 16).
    #[test]
    fn machine_16die_prefers_tp16() {
        let topo = Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 2,
                dies_per_board: 8,
            },
            Fabric::supernode(),
            DeviceSpec::ascend_910c(),
        );
        let best = best_plan(&ModelDesc::dense_50b(), &topo, &cfg_offload()).unwrap();
        assert_eq!(best.strategy.tp, 16, "best={}", explain(&best));
        assert_eq!(best.strategy.pp, 1);
    }

    /// On a *legacy* 16-die setup (2 boards over PCIe/Ethernet), TP16
    /// would cross the slow link — the planner keeps TP within a board
    /// and pays the PP bubble instead.
    #[test]
    fn legacy_16die_avoids_cross_board_tp() {
        let topo = Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 2,
                dies_per_board: 8,
            },
            Fabric::legacy(),
            DeviceSpec::a100_80g(),
        );
        let best = best_plan(&ModelDesc::dense_50b(), &topo, &cfg_offload()).unwrap();
        assert!(best.strategy.tp <= 8, "best={}", explain(&best));
        assert!(best.strategy.pp >= 2, "best={}", explain(&best));
    }

    #[test]
    fn plans_cover_cluster_exactly() {
        let topo = Topology::tiny();
        for c in plan(&ModelDesc::tiny_moe(), &topo, &cfg_offload()) {
            assert_eq!(c.strategy.device_count(), topo.device_count());
        }
    }

    #[test]
    fn builder_overrides_defaults() {
        let cfg = PlannerConfig::builder()
            .cube_efficiency(0.5)
            .microbatches(32)
            .allow_offload(true)
            .max_tp(16)
            .max_pp(8)
            .build();
        assert_eq!(cfg.cube_efficiency, 0.5);
        assert_eq!(cfg.microbatches, 32);
        assert!(cfg.allow_offload);
        assert_eq!(cfg.max_tp, 16);
        assert_eq!(cfg.max_pp, 8);
    }

    #[test]
    #[should_panic(expected = "cube_efficiency")]
    fn builder_rejects_nonsense_efficiency() {
        let _ = PlannerConfig::builder().cube_efficiency(0.0).build();
    }

    #[test]
    fn moe_model_gets_ep() {
        let topo = Topology::matrix384();
        let best = best_plan(&ModelDesc::deepseek_v3_like(), &topo, &cfg_offload()).unwrap();
        assert!(best.strategy.ep > 1, "best={}", explain(&best));
    }

    #[test]
    fn infeasible_without_offload_is_filtered() {
        // llama-8b training state (~16·8B = 128GB+acts) cannot fit 8×64GB
        // HBM with dp-only; every fitting plan must shard via tp·pp.
        let topo = Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 1,
                dies_per_board: 8,
            },
            Fabric::supernode(),
            DeviceSpec::ascend_910c(),
        );
        let cfg = PlannerConfig::default(); // no offload
        for c in plan(&ModelDesc::llama_8b(), &topo, &cfg) {
            assert!(c.fits_hbm);
            assert!(c.strategy.tp * c.strategy.pp >= 2, "{}", explain(&c));
        }
    }

    #[test]
    fn oversized_strategy_is_an_error_not_a_panic() {
        // regression: a strategy needing more devices than the topology
        // has used to index past the device table inside the collective
        // cost model (devices[id] panic); now it reports cleanly
        let topo = Topology::tiny(); // 8 devices
        let s = ParallelStrategy {
            dp: 4,
            tp: 8,
            pp: 1,
            ..Default::default()
        };
        assert_eq!(s.device_count(), 32);
        let err = try_evaluate(&ModelDesc::dense_30b(), &topo, &s, &cfg_offload()).unwrap_err();
        assert!(err.contains("32 devices"), "err: {err}");
        assert!(err.contains("only 8"), "err: {err}");
    }

    #[test]
    fn non_covering_strategy_is_an_error_not_a_panic() {
        let s = ParallelStrategy {
            dp: 3,
            tp: 2,
            pp: 1,
            ..Default::default()
        };
        // 6 devices claimed, 8 available: the rank grid cannot cover
        let err = try_assign_ranks(&s, 8).unwrap_err();
        assert!(err.contains("covers 6"), "err: {err}");
        assert!(err.contains("has 8"), "err: {err}");
        // and the checked evaluate path surfaces the same error
        let topo = Topology::tiny();
        assert!(try_evaluate(&ModelDesc::dense_30b(), &topo, &s, &cfg_offload()).is_err());
        // a covering strategy still round-trips through the same path
        let ok = ParallelStrategy {
            dp: 4,
            tp: 2,
            pp: 1,
            ..Default::default()
        };
        assert!(try_assign_ranks(&ok, 8).is_ok());
        assert!(try_evaluate(&ModelDesc::dense_30b(), &topo, &ok, &cfg_offload()).is_ok());
    }

    #[test]
    fn rank_grid_groups_are_disjoint_and_cover() {
        let s = ParallelStrategy {
            dp: 4,
            tp: 8,
            pp: 2,
            ..Default::default()
        };
        let grid = assign_ranks(&s, 64);
        let mut seen = std::collections::HashSet::new();
        for pp in 0..2 {
            for dp in 0..4 {
                for d in grid.tp_group(pp, dp, 0) {
                    assert!(seen.insert(d), "device {d} in two TP groups");
                }
            }
        }
        assert_eq!(seen.len(), 64);
        // TP groups are contiguous (board-local when tp ≤ 8)
        let g = grid.tp_group(1, 2, 0);
        assert_eq!(g[7].0 - g[0].0, 7);
    }
}
