//! `Layout(device_matrix, alias_name, tensor_map)` — the paper's §3.4
//! primary programming abstraction.
//!
//! A `Layout` describes the logical arrangement of accelerators
//! (`device_matrix`), names each dimension (`alias_name`), and maps
//! tensor dimensions onto device-matrix dimensions (`tensor_map`).
//! Calling `layout.apply(tensor_map, shape)` performs the *formal
//! derivation* of the shard strategy of Fig 6 — no physical slicing
//! happens here; runtime placement consumes the derived spec.

use std::collections::BTreeMap;

/// How one tensor dimension is split.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DimSharding {
    /// Replicated along this tensor dimension.
    Replicated,
    /// Split across the named device-matrix axes (outer→inner order;
    /// multiple axes = multi-level split, e.g. ("x","y") splits one
    /// tensor dim over both axes).
    Split(Vec<String>),
}

/// The derived parallel partitioning strategy for one tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    /// Per-tensor-dimension sharding.
    pub dims: Vec<DimSharding>,
    /// Number of shards along each tensor dimension.
    pub shard_counts: Vec<usize>,
    /// Device-matrix axes *not* used by any tensor dim — the tensor is
    /// replicated across them (these become the DP axes for weights).
    pub replicated_axes: Vec<String>,
    /// Total number of distinct shards (product of shard_counts).
    pub num_shards: usize,
    /// Replication degree (product of replicated axis sizes).
    pub replication: usize,
}

impl ShardSpec {
    /// Shape of one shard given the global tensor shape.
    pub fn shard_shape(&self, global: &[usize]) -> Vec<usize> {
        assert_eq!(global.len(), self.shard_counts.len());
        global
            .iter()
            .zip(&self.shard_counts)
            .map(|(&g, &c)| {
                assert!(g % c == 0, "dim {g} not divisible by {c} shards");
                g / c
            })
            .collect()
    }
}

/// Errors from layout construction/derivation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayoutError {
    AliasCountMismatch { axes: usize, aliases: usize },
    DuplicateAlias(String),
    UnknownAlias(String),
    AliasReused(String),
    RankMismatch { tensor_rank: usize, map_len: usize },
    ZeroAxis,
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LayoutError::AliasCountMismatch { axes, aliases } => write!(
                f,
                "device_matrix has {axes} axes but {aliases} alias names given"
            ),
            LayoutError::DuplicateAlias(a) => write!(f, "duplicate alias '{a}'"),
            LayoutError::UnknownAlias(a) => write!(f, "tensor_map references unknown alias '{a}'"),
            LayoutError::AliasReused(a) => {
                write!(f, "alias '{a}' used by more than one tensor dimension")
            }
            LayoutError::RankMismatch {
                tensor_rank,
                map_len,
            } => write!(
                f,
                "tensor rank {tensor_rank} does not match tensor_map length {map_len}"
            ),
            LayoutError::ZeroAxis => write!(f, "device_matrix axes must be positive"),
        }
    }
}

impl std::error::Error for LayoutError {}

/// One entry of a tensor_map: which device axes shard this tensor dim.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapDim {
    /// "None" in the paper's notation — replicated.
    None,
    /// Shard along one named axis.
    Axis(&'static str),
    /// Shard along several axes jointly (multi-level).
    Axes(Vec<&'static str>),
}

impl MapDim {
    fn axis_names(&self) -> Vec<String> {
        match self {
            MapDim::None => vec![],
            MapDim::Axis(a) => vec![a.to_string()],
            MapDim::Axes(v) => v.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// The Layout object (paper Listing 2).
#[derive(Debug, Clone, PartialEq)]
pub struct Layout {
    device_matrix: Vec<usize>,
    alias_name: Vec<String>,
    axis_size: BTreeMap<String, usize>,
}

impl Layout {
    /// `Layout(device_matrix, alias_name)`.
    pub fn new(device_matrix: &[usize], alias_name: &[&str]) -> Result<Self, LayoutError> {
        if device_matrix.len() != alias_name.len() {
            return Err(LayoutError::AliasCountMismatch {
                axes: device_matrix.len(),
                aliases: alias_name.len(),
            });
        }
        if device_matrix.iter().any(|&a| a == 0) {
            return Err(LayoutError::ZeroAxis);
        }
        let mut axis_size = BTreeMap::new();
        for (&size, &name) in device_matrix.iter().zip(alias_name) {
            if axis_size.insert(name.to_string(), size).is_some() {
                return Err(LayoutError::DuplicateAlias(name.to_string()));
            }
        }
        Ok(Self {
            device_matrix: device_matrix.to_vec(),
            alias_name: alias_name.iter().map(|s| s.to_string()).collect(),
            axis_size,
        })
    }

    pub fn device_count(&self) -> usize {
        self.device_matrix.iter().product()
    }

    pub fn axes(&self) -> &[String] {
        &self.alias_name
    }

    pub fn axis_size(&self, name: &str) -> Option<usize> {
        self.axis_size.get(name).copied()
    }

    /// `layout(tensor_map)` — derive the shard strategy for a tensor of
    /// rank `tensor_map.len()`. This is the three-stage procedure of
    /// Fig 6: start replicated, then shard dim k along its mapped axes.
    pub fn apply(&self, tensor_map: &[MapDim]) -> Result<ShardSpec, LayoutError> {
        let mut used: BTreeMap<String, usize> = BTreeMap::new();
        let mut dims = Vec::with_capacity(tensor_map.len());
        let mut shard_counts = Vec::with_capacity(tensor_map.len());
        for (dim_idx, m) in tensor_map.iter().enumerate() {
            let names = m.axis_names();
            let mut count = 1usize;
            for n in &names {
                let size = self
                    .axis_size
                    .get(n)
                    .copied()
                    .ok_or_else(|| LayoutError::UnknownAlias(n.clone()))?;
                if let Some(&prev) = used.get(n) {
                    if prev != dim_idx {
                        return Err(LayoutError::AliasReused(n.clone()));
                    }
                }
                used.insert(n.clone(), dim_idx);
                count *= size;
            }
            dims.push(if names.is_empty() {
                DimSharding::Replicated
            } else {
                DimSharding::Split(names)
            });
            shard_counts.push(count);
        }
        let replicated_axes: Vec<String> = self
            .alias_name
            .iter()
            .filter(|a| !used.contains_key(*a))
            .cloned()
            .collect();
        let replication = replicated_axes
            .iter()
            .map(|a| self.axis_size[a])
            .product();
        let num_shards = shard_counts.iter().product();
        Ok(ShardSpec {
            dims,
            shard_counts,
            replicated_axes,
            num_shards,
            replication,
        })
    }

    /// Validate a spec against a concrete tensor shape.
    pub fn check_shape(
        &self,
        spec: &ShardSpec,
        shape: &[usize],
    ) -> Result<Vec<usize>, LayoutError> {
        if shape.len() != spec.shard_counts.len() {
            return Err(LayoutError::RankMismatch {
                tensor_rank: shape.len(),
                map_len: spec.shard_counts.len(),
            });
        }
        Ok(spec.shard_shape(shape))
    }

    /// Which device (flat rank within the device matrix) holds the
    /// shard at multi-index `coords` along the *device matrix* axes.
    /// Row-major over device_matrix.
    pub fn rank_of(&self, coords: &[usize]) -> usize {
        assert_eq!(coords.len(), self.device_matrix.len());
        let mut rank = 0;
        for (c, &n) in coords.iter().zip(&self.device_matrix) {
            assert!(*c < n);
            rank = rank * n + c;
        }
        rank
    }

    /// Inverse of `rank_of`.
    pub fn coords_of(&self, mut rank: usize) -> Vec<usize> {
        let mut coords = vec![0; self.device_matrix.len()];
        for i in (0..self.device_matrix.len()).rev() {
            coords[i] = rank % self.device_matrix[i];
            rank /= self.device_matrix[i];
        }
        coords
    }

    /// For every device rank, compute which tensor shard (multi-index
    /// over tensor dims) it holds under `spec`. Devices along
    /// replicated axes map to the same shard — this is the full
    /// Fig 6 placement.
    pub fn placement(&self, spec: &ShardSpec) -> Vec<Vec<usize>> {
        let n = self.device_count();
        let mut out = Vec::with_capacity(n);
        for rank in 0..n {
            let coords = self.coords_of(rank);
            let mut shard_idx = Vec::with_capacity(spec.dims.len());
            for dim in &spec.dims {
                match dim {
                    DimSharding::Replicated => shard_idx.push(0),
                    DimSharding::Split(axes) => {
                        // combine the coords of all axes, outer→inner
                        let mut idx = 0;
                        for a in axes {
                            let ai = self.alias_name.iter().position(|x| x == a).unwrap();
                            idx = idx * self.device_matrix[ai] + coords[ai];
                        }
                        shard_idx.push(idx);
                    }
                }
            }
            out.push(shard_idx);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Listing 2: 4 accelerators as a 2×2 device matrix,
    /// tensor_map = ("x", "y") on a (2,2) tensor.
    #[test]
    fn listing2_example() {
        let layout = Layout::new(&[2, 2], &["x", "y"]).unwrap();
        let spec = layout
            .apply(&[MapDim::Axis("x"), MapDim::Axis("y")])
            .unwrap();
        assert_eq!(spec.shard_counts, vec![2, 2]);
        assert_eq!(spec.num_shards, 4);
        assert_eq!(spec.replication, 1);
        assert_eq!(layout.check_shape(&spec, &[2, 2]).unwrap(), vec![1, 1]);
    }

    /// Fig 6 staging: dim0 along "x" only — dim1 replicated; the "y"
    /// axis replicates the tensor.
    #[test]
    fn partial_map_replicates_rest() {
        let layout = Layout::new(&[2, 2], &["x", "y"]).unwrap();
        let spec = layout.apply(&[MapDim::Axis("x"), MapDim::None]).unwrap();
        assert_eq!(spec.shard_counts, vec![2, 1]);
        assert_eq!(spec.replicated_axes, vec!["y".to_string()]);
        assert_eq!(spec.replication, 2);
        assert_eq!(spec.num_shards, 2);
    }

    #[test]
    fn multi_axis_split() {
        // 8 devices as (2,2,2); shard dim0 over both x and z: 4-way
        let layout = Layout::new(&[2, 2, 2], &["x", "y", "z"]).unwrap();
        let spec = layout
            .apply(&[MapDim::Axes(vec!["x", "z"]), MapDim::Axis("y")])
            .unwrap();
        assert_eq!(spec.shard_counts, vec![4, 2]);
        assert_eq!(spec.num_shards, 8);
        assert_eq!(spec.replication, 1);
    }

    #[test]
    fn placement_covers_all_shards() {
        let layout = Layout::new(&[2, 2], &["x", "y"]).unwrap();
        let spec = layout
            .apply(&[MapDim::Axis("x"), MapDim::Axis("y")])
            .unwrap();
        let placement = layout.placement(&spec);
        assert_eq!(placement.len(), 4);
        let mut seen: Vec<_> = placement.clone();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 4, "each device holds a distinct shard");
    }

    #[test]
    fn placement_replication_groups() {
        // dp axis "d" of size 2 replicates; tp axis "t" shards dim1
        let layout = Layout::new(&[2, 4], &["d", "t"]).unwrap();
        let spec = layout.apply(&[MapDim::None, MapDim::Axis("t")]).unwrap();
        let placement = layout.placement(&spec);
        // ranks 0..4 (d=0) and 4..8 (d=1) hold the same shard sequence
        assert_eq!(&placement[0..4], &placement[4..8]);
    }

    #[test]
    fn error_cases() {
        assert!(matches!(
            Layout::new(&[2, 2], &["x"]),
            Err(LayoutError::AliasCountMismatch { .. })
        ));
        assert!(matches!(
            Layout::new(&[2, 2], &["x", "x"]),
            Err(LayoutError::DuplicateAlias(_))
        ));
        assert!(matches!(
            Layout::new(&[0, 2], &["x", "y"]),
            Err(LayoutError::ZeroAxis)
        ));
        let layout = Layout::new(&[2, 2], &["x", "y"]).unwrap();
        assert!(matches!(
            layout.apply(&[MapDim::Axis("q"), MapDim::None]),
            Err(LayoutError::UnknownAlias(_))
        ));
        assert!(matches!(
            layout.apply(&[MapDim::Axis("x"), MapDim::Axis("x")]),
            Err(LayoutError::AliasReused(_))
        ));
    }

    #[test]
    fn rank_coords_roundtrip() {
        let layout = Layout::new(&[2, 3, 4], &["a", "b", "c"]).unwrap();
        for rank in 0..24 {
            assert_eq!(layout.rank_of(&layout.coords_of(rank)), rank);
        }
    }
}
