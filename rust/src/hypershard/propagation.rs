//! Sharding propagation with automatic collective insertion.
//!
//! This is the machinery behind the paper's Fig 5(b): the researcher
//! declares layouts for a few tensors and the framework derives the
//! rest — including which communication operators must be inserted and
//! where. The rules are the standard SPMD partitioning algebra
//! (GSPMD-style) specialized to the ops the transformer workloads use.

use super::layout::{DimSharding, ShardSpec};
use crate::graph::CollectiveKind;

/// A required communication op discovered during propagation.
#[derive(Debug, Clone, PartialEq)]
pub struct CommRequirement {
    pub kind: CollectiveKind,
    /// Device axes the collective runs over.
    pub axes: Vec<String>,
    /// Why it was inserted (for the explain output).
    pub reason: String,
}

/// Result of propagating through one op.
#[derive(Debug, Clone, PartialEq)]
pub struct Propagated {
    pub output: ShardSpec,
    pub comms: Vec<CommRequirement>,
}

fn replicated(rank: usize) -> ShardSpec {
    ShardSpec {
        dims: vec![DimSharding::Replicated; rank],
        shard_counts: vec![1; rank],
        replicated_axes: vec![],
        num_shards: 1,
        replication: 1,
    }
}

fn split_axes(d: &DimSharding) -> Vec<String> {
    match d {
        DimSharding::Replicated => vec![],
        DimSharding::Split(a) => a.clone(),
    }
}

fn shard_count(d: &DimSharding, counts: usize) -> usize {
    match d {
        DimSharding::Replicated => 1,
        DimSharding::Split(_) => counts,
    }
}

/// Propagate through `C[m,n] = A[m,k] @ B[k,n]`.
///
/// Rules:
/// - A.m split  → C.m split on the same axes (row parallel, no comm).
/// - B.n split  → C.n split on the same axes (column parallel, no comm).
/// - A.k and B.k split on the same axes → partial sums on every device
///   → insert **AllReduce** over those axes (the Megatron TP pattern).
/// - A.k split but B.k replicated (or mismatched) → insert **AllGather**
///   on A's k axes first (resharding), no partial sums.
pub fn matmul(a: &ShardSpec, b: &ShardSpec) -> Propagated {
    assert_eq!(a.dims.len(), 2, "matmul lhs must be rank 2");
    assert_eq!(b.dims.len(), 2, "matmul rhs must be rank 2");
    let mut comms = Vec::new();

    let a_k = split_axes(&a.dims[1]);
    let b_k = split_axes(&b.dims[0]);

    let contraction_axes: Vec<String>;
    if !a_k.is_empty() && a_k == b_k {
        // matched contraction sharding: partial sums -> all-reduce
        contraction_axes = a_k.clone();
        comms.push(CommRequirement {
            kind: CollectiveKind::AllReduce,
            axes: contraction_axes.clone(),
            reason: format!(
                "contraction dim sharded on {:?}: partial sums must be all-reduced",
                contraction_axes
            ),
        });
    } else {
        // mismatched/unilateral sharding of k: gather the sharded side(s)
        if !a_k.is_empty() {
            comms.push(CommRequirement {
                kind: CollectiveKind::AllGather,
                axes: a_k.clone(),
                reason: "lhs contraction dim sharded but rhs not matching: all-gather lhs".into(),
            });
        }
        if !b_k.is_empty() {
            comms.push(CommRequirement {
                kind: CollectiveKind::AllGather,
                axes: b_k.clone(),
                reason: "rhs contraction dim sharded but lhs not matching: all-gather rhs".into(),
            });
        }
    }

    let m_axes = split_axes(&a.dims[0]);
    let n_axes = split_axes(&b.dims[1]);
    let out = ShardSpec {
        dims: vec![
            if m_axes.is_empty() {
                DimSharding::Replicated
            } else {
                DimSharding::Split(m_axes)
            },
            if n_axes.is_empty() {
                DimSharding::Replicated
            } else {
                DimSharding::Split(n_axes)
            },
        ],
        shard_counts: vec![
            shard_count(&a.dims[0], a.shard_counts[0]),
            shard_count(&b.dims[1], b.shard_counts[1]),
        ],
        replicated_axes: vec![],
        num_shards: shard_count(&a.dims[0], a.shard_counts[0])
            * shard_count(&b.dims[1], b.shard_counts[1]),
        replication: 1,
    };
    Propagated {
        output: out,
        comms,
    }
}

/// Elementwise binary op: both inputs must agree; mismatches force an
/// all-gather of the more-sharded operand to the lesser sharding.
pub fn elementwise(a: &ShardSpec, b: &ShardSpec) -> Propagated {
    assert_eq!(a.dims.len(), b.dims.len());
    let mut comms = Vec::new();
    let mut dims = Vec::with_capacity(a.dims.len());
    let mut counts = Vec::with_capacity(a.dims.len());
    for i in 0..a.dims.len() {
        let ax = split_axes(&a.dims[i]);
        let bx = split_axes(&b.dims[i]);
        if ax == bx {
            dims.push(a.dims[i].clone());
            counts.push(a.shard_counts[i]);
        } else {
            // reshard to the intersection (here: replicate)
            for (side, axes) in [("lhs", &ax), ("rhs", &bx)] {
                if !axes.is_empty() {
                    comms.push(CommRequirement {
                        kind: CollectiveKind::AllGather,
                        axes: axes.clone(),
                        reason: format!("elementwise dim {i} sharding mismatch: gather {side}"),
                    });
                }
            }
            dims.push(DimSharding::Replicated);
            counts.push(1);
        }
    }
    let num = counts.iter().product();
    Propagated {
        output: ShardSpec {
            dims,
            shard_counts: counts,
            replicated_axes: vec![],
            num_shards: num,
            replication: 1,
        },
        comms,
    }
}

/// Reduction over one tensor dim: if that dim is sharded, partial
/// results need an all-reduce over its axes.
pub fn reduce(input: &ShardSpec, dim: usize) -> Propagated {
    let mut comms = Vec::new();
    let axes = split_axes(&input.dims[dim]);
    if !axes.is_empty() {
        comms.push(CommRequirement {
            kind: CollectiveKind::AllReduce,
            axes,
            reason: format!("reduction over sharded dim {dim}"),
        });
    }
    let mut dims = input.dims.clone();
    let mut counts = input.shard_counts.clone();
    dims.remove(dim);
    counts.remove(dim);
    let num = counts.iter().product();
    Propagated {
        output: ShardSpec {
            dims,
            shard_counts: counts,
            replicated_axes: input.replicated_axes.clone(),
            num_shards: num,
            replication: input.replication,
        },
        comms,
    }
}

/// MoE dispatch: tokens sharded on the batch dim must be re-routed to
/// expert-parallel ranks — an all-to-all over the EP axes, and another
/// one to return (combine). This is the §3.3 EP communication.
pub fn moe_dispatch(tokens: &ShardSpec, ep_axes: &[String]) -> Propagated {
    let mut comms = Vec::new();
    if !ep_axes.is_empty() {
        comms.push(CommRequirement {
            kind: CollectiveKind::AllToAll,
            axes: ep_axes.to_vec(),
            reason: "MoE dispatch: route tokens to their experts".into(),
        });
        comms.push(CommRequirement {
            kind: CollectiveKind::AllToAll,
            axes: ep_axes.to_vec(),
            reason: "MoE combine: return expert outputs to token owners".into(),
        });
    }
    Propagated {
        output: tokens.clone(),
        comms,
    }
}

/// Fully replicated spec of a given rank (for declared inputs).
pub fn replicated_spec(rank: usize) -> ShardSpec {
    replicated(rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypershard::layout::{Layout, MapDim};

    fn tp_layout() -> Layout {
        Layout::new(&[2, 4], &["dp", "tp"]).unwrap()
    }

    #[test]
    fn column_parallel_no_comm() {
        // A replicated, B sharded on n ("tp"): Megatron column-parallel
        let l = tp_layout();
        let a = replicated_spec(2);
        let b = l.apply(&[MapDim::None, MapDim::Axis("tp")]).unwrap();
        let p = matmul(&a, &b);
        assert!(p.comms.is_empty());
        assert_eq!(p.output.shard_counts, vec![1, 4]);
    }

    #[test]
    fn row_parallel_inserts_allreduce() {
        // A sharded on k, B sharded on k: row-parallel -> all-reduce
        let l = tp_layout();
        let a = l.apply(&[MapDim::None, MapDim::Axis("tp")]).unwrap();
        let b = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let p = matmul(&a, &b);
        assert_eq!(p.comms.len(), 1);
        assert_eq!(p.comms[0].kind, CollectiveKind::AllReduce);
        assert_eq!(p.comms[0].axes, vec!["tp".to_string()]);
        assert_eq!(p.output.num_shards, 1); // output replicated
    }

    #[test]
    fn mismatched_contraction_gathers() {
        let l = tp_layout();
        let a = l.apply(&[MapDim::None, MapDim::Axis("tp")]).unwrap();
        let b = replicated_spec(2);
        let p = matmul(&a, &b);
        assert_eq!(p.comms.len(), 1);
        assert_eq!(p.comms[0].kind, CollectiveKind::AllGather);
    }

    #[test]
    fn elementwise_agreement_passes_through() {
        let l = tp_layout();
        let a = l.apply(&[MapDim::Axis("dp"), MapDim::Axis("tp")]).unwrap();
        let b = l.apply(&[MapDim::Axis("dp"), MapDim::Axis("tp")]).unwrap();
        let p = elementwise(&a, &b);
        assert!(p.comms.is_empty());
        assert_eq!(p.output.shard_counts, vec![2, 4]);
    }

    #[test]
    fn elementwise_mismatch_reshards() {
        let l = tp_layout();
        let a = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
        let b = l.apply(&[MapDim::None, MapDim::None]).unwrap();
        let p = elementwise(&a, &b);
        assert_eq!(p.comms.len(), 1);
        assert_eq!(p.output.shard_counts, vec![1, 1]);
    }

    #[test]
    fn reduce_over_sharded_dim() {
        let l = tp_layout();
        let a = l.apply(&[MapDim::Axis("dp"), MapDim::Axis("tp")]).unwrap();
        let p = reduce(&a, 1);
        assert_eq!(p.comms.len(), 1);
        assert_eq!(p.comms[0].kind, CollectiveKind::AllReduce);
        assert_eq!(p.output.dims.len(), 1);
    }

    #[test]
    fn moe_dispatch_two_all_to_alls() {
        let l = Layout::new(&[4, 8], &["dp", "ep"]).unwrap();
        let tokens = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
        let p = moe_dispatch(&tokens, &["ep".to_string()]);
        assert_eq!(p.comms.len(), 2);
        assert!(p.comms.iter().all(|c| c.kind == CollectiveKind::AllToAll));
    }
}
