//! Resharding: transitioning tensors between layouts.
//!
//! The paper's RL workflow (§3.3c) co-deploys training and inference of
//! the same model under *different* parallel strategies; every
//! actor-learner sync moves the weights from the training layout to the
//! rollout layout. HyperShard derives the transition plan from the two
//! `ShardSpec`s: which collectives are needed per tensor dimension, how
//! many bytes cross the fabric, and what it costs on a given topology.

use super::layout::{DimSharding, ShardSpec};
use crate::collectives;
use crate::graph::CollectiveKind;
use crate::supernode::{DeviceId, Fleet, Topology};

/// One step of a resharding plan.
#[derive(Debug, Clone, PartialEq)]
pub struct ReshardStep {
    pub kind: CollectiveKind,
    /// Tensor dimension the step operates on.
    pub dim: usize,
    /// Device axes involved.
    pub axes: Vec<String>,
    pub reason: String,
}

/// The full transition plan for one tensor.
#[derive(Debug, Clone, Default)]
pub struct ReshardPlan {
    pub steps: Vec<ReshardStep>,
    /// Bytes each rank must move per collective, given the global
    /// tensor byte size.
    pub bytes_factor: f64,
}

fn axes_of(d: &DimSharding) -> Vec<String> {
    match d {
        DimSharding::Replicated => vec![],
        DimSharding::Split(a) => a.clone(),
    }
}

/// Derive the plan from a source and destination spec (same rank).
///
/// Per dimension:
/// - sharded → replicated: **all-gather** over the source axes.
/// - replicated → sharded: local **slice** (no comm; each rank keeps
///   its part — modeled as a zero-cost step).
/// - sharded → sharded on *different* axes: **all-to-all** over the
///   union (the DP↔EP transition in MoE weight sync).
/// - identical sharding: nothing.
pub fn plan_reshard(src: &ShardSpec, dst: &ShardSpec) -> ReshardPlan {
    assert_eq!(
        src.dims.len(),
        dst.dims.len(),
        "reshard requires equal tensor rank"
    );
    let mut plan = ReshardPlan {
        steps: Vec::new(),
        bytes_factor: 0.0,
    };
    for (dim, (s, d)) in src.dims.iter().zip(&dst.dims).enumerate() {
        let sa = axes_of(s);
        let da = axes_of(d);
        if sa == da {
            continue;
        }
        if !sa.is_empty() && da.is_empty() {
            plan.steps.push(ReshardStep {
                kind: CollectiveKind::AllGather,
                dim,
                axes: sa.clone(),
                reason: format!("dim {dim}: sharded {:?} -> replicated", sa),
            });
            // gather moves (p-1)/p of the tensor; approximate with 1.0
            plan.bytes_factor += 1.0;
        } else if sa.is_empty() && !da.is_empty() {
            plan.steps.push(ReshardStep {
                kind: CollectiveKind::P2p,
                dim,
                axes: da.clone(),
                reason: format!("dim {dim}: replicated -> sharded {:?} (local slice)", da),
            });
        } else {
            let mut union = sa.clone();
            for a in &da {
                if !union.contains(a) {
                    union.push(a.clone());
                }
            }
            plan.steps.push(ReshardStep {
                kind: CollectiveKind::AllToAll,
                dim,
                axes: union,
                reason: format!("dim {dim}: re-shard {:?} -> {:?}", sa, da),
            });
            plan.bytes_factor += 1.0;
        }
    }
    plan
}

/// The pure-DP partitioning of a training state over `shards` devices.
/// Axis names encode the shard count so two different counts compare
/// as different axes — exactly the re-shard (all-to-all) case of
/// [`plan_reshard`]. Shared by `trainer::elastic` (lease changes) and
/// `hypershard::autotune` (pricing strategy transitions).
pub fn dp_shard_spec(shards: usize) -> ShardSpec {
    ShardSpec {
        dims: vec![
            DimSharding::Split(vec![format!("dp{shards}")]),
            DimSharding::Replicated,
        ],
        shard_counts: vec![shards, 1],
        replicated_axes: vec![],
        num_shards: shards,
        replication: 1,
    }
}

/// Estimated wall time of a plan on a topology: each comm step costed
/// over `group`, moving `tensor_bytes / num_src_shards` per rank.
pub fn reshard_time(
    plan: &ReshardPlan,
    topo: &Topology,
    group: &[DeviceId],
    tensor_bytes: f64,
    src_shards: usize,
) -> f64 {
    let per_rank = tensor_bytes / src_shards.max(1) as f64;
    plan.steps
        .iter()
        .filter(|s| s.kind != CollectiveKind::P2p)
        .map(|s| collectives::cost(topo, s.kind, per_rank, group).time)
        .sum()
}

/// [`reshard_time`] over a *fleet-global* group: same plan walk, each
/// comm step priced by [`collectives::cost_fleet`] — so a group
/// confined to one pool costs bit-identically to the bare topology
/// path, and a group spanning supernodes pays the inter-node
/// all-to-all (the price the `LeaseBroker` weighs before crossing).
pub fn reshard_time_fleet(
    plan: &ReshardPlan,
    fleet: &Fleet,
    group: &[DeviceId],
    tensor_bytes: f64,
    src_shards: usize,
) -> f64 {
    let per_rank = tensor_bytes / src_shards.max(1) as f64;
    plan.steps
        .iter()
        .filter(|s| s.kind != CollectiveKind::P2p)
        .map(|s| collectives::cost_fleet(fleet, s.kind, per_rank, group).time)
        .sum()
}

/// The RL actor-learner weight-sync scenario (E9 companion): the
/// learner trains with one spec; `actors` rollout replicas each need a
/// full copy — an all-gather to the learner group plus a broadcast to
/// every actor group. Returns (plan description, total seconds).
pub fn actor_weight_sync_time(
    topo: &Topology,
    learner_group: &[DeviceId],
    actor_groups: &[Vec<DeviceId>],
    weight_bytes: f64,
    learner_shards: usize,
) -> f64 {
    // gather the sharded weights inside the learner group
    let gather =
        collectives::cost(topo, CollectiveKind::AllGather, weight_bytes / learner_shards.max(1) as f64, learner_group)
            .time;
    // broadcast to each actor group (pipelined over groups: take max)
    let bcast = actor_groups
        .iter()
        .map(|g| {
            let mut group = g.clone();
            group.push(learner_group[0]);
            collectives::cost(topo, CollectiveKind::Broadcast, weight_bytes, &group).time
        })
        .fold(0.0f64, f64::max);
    gather + bcast
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypershard::layout::{Layout, MapDim};
    use crate::supernode::Topology;

    fn layout() -> Layout {
        Layout::new(&[4, 8], &["dp", "tp"]).unwrap()
    }

    #[test]
    fn identical_specs_need_nothing() {
        let l = layout();
        let s = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let plan = plan_reshard(&s, &s.clone());
        assert!(plan.steps.is_empty());
    }

    #[test]
    fn shard_to_replicated_gathers() {
        let l = layout();
        let src = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let dst = l.apply(&[MapDim::None, MapDim::None]).unwrap();
        let plan = plan_reshard(&src, &dst);
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].kind, CollectiveKind::AllGather);
        assert_eq!(plan.steps[0].axes, vec!["tp".to_string()]);
    }

    #[test]
    fn replicated_to_shard_is_local() {
        let l = layout();
        let src = l.apply(&[MapDim::None, MapDim::None]).unwrap();
        let dst = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
        let plan = plan_reshard(&src, &dst);
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].kind, CollectiveKind::P2p);
        assert_eq!(plan.bytes_factor, 0.0); // no fabric traffic
    }

    #[test]
    fn axis_swap_is_all_to_all() {
        let l = layout();
        let src = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let dst = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
        let plan = plan_reshard(&src, &dst);
        assert_eq!(plan.steps.len(), 1);
        assert_eq!(plan.steps[0].kind, CollectiveKind::AllToAll);
        assert!(plan.steps[0].axes.contains(&"tp".to_string()));
        assert!(plan.steps[0].axes.contains(&"dp".to_string()));
    }

    #[test]
    fn reshard_time_positive_and_scales() {
        let l = layout();
        let topo = Topology::matrix384();
        let src = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let dst = l.apply(&[MapDim::None, MapDim::None]).unwrap();
        let plan = plan_reshard(&src, &dst);
        let group: Vec<_> = (0..8).map(crate::supernode::DeviceId).collect();
        let t1 = reshard_time(&plan, &topo, &group, 1e9, 8);
        let t2 = reshard_time(&plan, &topo, &group, 2e9, 8);
        assert!(t1 > 0.0);
        assert!(t2 > t1 * 1.5);
    }

    #[test]
    fn fleet_reshard_single_pool_bit_identical_and_crossing_costs_more() {
        let l = layout();
        let src = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
        let dst = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
        let plan = plan_reshard(&src, &dst);
        let fleet = crate::supernode::Fleet::dual_supernode();
        let intra: Vec<_> = (0..16).map(crate::supernode::DeviceId).collect();
        let t_topo = reshard_time(&plan, &fleet.pools[0].topo, &intra, 96e9, 16);
        let t_fleet = reshard_time_fleet(&plan, &fleet, &intra, 96e9, 16);
        assert_eq!(t_topo.to_bits(), t_fleet.to_bits());
        let spanning: Vec<_> = (0..8).chain(32..40).map(crate::supernode::DeviceId).collect();
        let t_span = reshard_time_fleet(&plan, &fleet, &spanning, 96e9, 16);
        assert!(t_span > t_fleet * 2.0, "intra={t_fleet} span={t_span}");
    }

    #[test]
    fn weight_sync_faster_on_supernode() {
        let sn = Topology::matrix384();
        let lg = Topology::legacy_cluster(48);
        let learner: Vec<_> = (0..16).map(crate::supernode::DeviceId).collect();
        let actors: Vec<Vec<_>> = (1..4)
            .map(|g| (g * 16..(g + 1) * 16).map(crate::supernode::DeviceId).collect())
            .collect();
        let w = 16e9; // 8B params bf16
        let t_sn = actor_weight_sync_time(&sn, &learner, &actors, w, 16);
        let t_lg = actor_weight_sync_time(&lg, &learner, &actors, w, 16);
        assert!(t_lg / t_sn > 3.0, "sn={t_sn} lg={t_lg}");
    }
}
