//! Serving scenario tests (ISSUE 2 acceptance): on the shared smoke
//! presets, the pool-offload configuration sustains a strictly higher
//! max-QPS-under-p99-SLO operating point — and admits more concurrent
//! context — than the no-offload baseline. The same presets feed
//! `benches/bench_serving.rs`, whose emitted metrics CI gates against
//! `BENCH_baseline.json`; the bounds asserted here are strictly
//! tighter than the gate's thresholds, so green tests imply a green
//! gate.

use hyperparallel::hyperoffload::kvcache::KvCacheConfig;
use hyperparallel::serving::{
    max_qps_under_slo, rate_sweep, run_scenario, simulate, smoke_scenario, smoke_slo,
    ArrivalProcess, CostModel, MemoryPolicy, Request, ServingConfig, TenantProfile,
    SMOKE_RATES,
};
use hyperparallel::sim::TraceMode;

#[test]
fn offload_sustains_higher_max_qps_under_p99_slo() {
    let slo = smoke_slo();
    let base_points = rate_sweep(&smoke_scenario(SMOKE_RATES[0], 0.0, 2), &SMOKE_RATES, &slo);
    let off_points = rate_sweep(&smoke_scenario(SMOKE_RATES[0], 0.2, 2), &SMOKE_RATES, &slo);

    let base = max_qps_under_slo(&base_points).expect("baseline must attain at light load");
    let off = max_qps_under_slo(&off_points).expect("offload must attain at light load");

    // The acceptance bar, with margin over the CI gate's thresholds
    // (gate: qps gain > ~0.98, ctx gain > ~1.06, abs qps > 51).
    assert!(
        off.rate > base.rate,
        "pool offload must sustain a strictly higher rate: {} vs {}",
        off.rate,
        base.rate
    );
    assert!(
        off.rate / base.rate >= 1.15,
        "qps gain too small: {} / {}",
        off.rate,
        base.rate
    );
    assert!(off.rate >= 60.0, "offload operating point too low: {}", off.rate);
    assert!(
        off.peak_context_tokens as f64 >= 1.25 * base.peak_context_tokens as f64,
        "admitted context gain too small: {} vs {}",
        off.peak_context_tokens,
        base.peak_context_tokens
    );
    assert!(off.p99_ttft <= slo.ttft_p99 && off.p99_tpot <= slo.tpot_p99);

    // At the top offered rate the baseline visibly thrashes or blocks.
    let base_top = base_points.last().unwrap();
    assert!(
        !base_top.attains_slo,
        "baseline should fail the SLO at {} req/s",
        base_top.rate
    );
    // The no-offload fleet's admitted context is capped by its HBM
    // page budget (4096 tokens per replica on the smoke device).
    assert!(
        base_points.iter().all(|p| p.peak_context_tokens <= 2 * 4096),
        "baseline context exceeded the HBM budget"
    );
    // The offload fleet never demotes in this regime (capacity win,
    // not streaming win) and never preempts at its operating point.
    assert_eq!(off.rejected, 0);
}

#[test]
fn conservation_and_budget_invariants_hold_under_load() {
    let sc = smoke_scenario(90.0, 0.2, 2);
    let n_submitted = sc.workload.generate(sc.horizon).len() as u64;
    let rep = run_scenario(&sc);
    assert_eq!(
        rep.completed() as u64 + rep.rejected,
        n_submitted,
        "every request completes or is rejected"
    );
    let produced: u64 = rep.outcomes.iter().map(|o| o.output_tokens as u64).sum();
    // preempted-and-restarted requests discard produced tokens, so the
    // decode counter is an upper bound that matches exactly when no
    // preemption occurred
    assert!(rep.decoded_tokens >= produced);
    if rep.preemptions == 0 {
        assert_eq!(rep.decoded_tokens, produced);
    }
    for o in &rep.outcomes {
        assert!(o.arrival < o.first_token, "ttft must be positive");
        assert!(o.first_token <= o.finish);
        assert!(o.output_tokens >= 1);
    }
    // peak admitted context fits the fleet's total page budget
    let kv = &sc.serving.cost.kv;
    let hbm_tokens = (kv.kv_token_capacity(0.2) / kv.tokens_per_page) * kv.tokens_per_page;
    let pool_tokens = sc.serving.pool_pages * kv.tokens_per_page;
    let budget = sc.serving.fleet * (hbm_tokens + pool_tokens);
    assert!(
        rep.peak_context_tokens <= budget,
        "peak context {} exceeds fleet budget {}",
        rep.peak_context_tokens,
        budget
    );
}

fn tiny_kv(pages_at_f0: u64) -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 1024,
        tokens_per_page: 16,
        weight_bytes: 1 << 20,
        hbm_usable: (1 << 20) + pages_at_f0 * 16 * 1024,
        hbm_bw: 1e12,
        pool_bw: 100e9,
        attn_tokens_per_s: 40e6,
    }
}

#[test]
fn demotion_path_beats_preemption_thrash() {
    // HBM holds 16 pages; 6 slots of ~60-token sequences need ~24, and
    // near-simultaneous arrivals keep every slot contended — the pool
    // policy demotes cold pages, the baseline thrashes.
    let reqs: Vec<Request> = (0..40)
        .map(|id| Request {
            id,
            tenant: 0,
            session: 0,
            arrival: id as f64 * 1e-5,
            prompt_tokens: 48,
            shared_prefix_tokens: 0,
            output_tokens: 12,
        })
        .collect();
    let mk = |frac: f64, policy: MemoryPolicy| ServingConfig {
        fleet: 1,
        slots: 6,
        max_seq: 512,
        cost: CostModel::new(tiny_kv(16), frac),
        policy,
        pool_pages: 64,
        max_preemptions: 4,
        trace_mode: TraceMode::Indexed,
    };
    let off = simulate(&mk(0.1, MemoryPolicy::PoolOffload), &reqs);
    let base = simulate(&mk(0.0, MemoryPolicy::NoOffload), &reqs);
    assert!(off.demotions > 0, "pool policy must demote under pressure");
    assert_eq!(off.rejected, 0, "demotion absorbs the pressure");
    assert!(base.preemptions > 0, "baseline must thrash under pressure");
    assert!(
        off.completed() >= base.completed(),
        "offload completes no fewer: {} vs {}",
        off.completed(),
        base.completed()
    );
    let qps = |r: &hyperparallel::serving::ServingReport| r.completed() as f64 / r.makespan;
    assert!(
        qps(&off) > qps(&base),
        "offload throughput {} must beat baseline {}",
        qps(&off),
        qps(&base)
    );
}

#[test]
fn bursty_and_diurnal_traffic_flow_end_to_end() {
    let mut sc = smoke_scenario(40.0, 0.2, 2);
    sc.workload.arrival = ArrivalProcess::Bursty {
        rate_on: 120.0,
        rate_off: 8.0,
        mean_on: 0.5,
        mean_off: 1.5,
    };
    let bursty = run_scenario(&sc);
    assert!(bursty.completed() > 50);
    assert!(bursty.ttft_pct(99.0) >= bursty.ttft_pct(50.0));

    sc.workload.arrival = ArrivalProcess::Diurnal {
        tenants: vec![
            TenantProfile {
                base_rate: 30.0,
                amplitude: 0.8,
                period: 4.0,
                phase: 0.0,
            },
            TenantProfile {
                base_rate: 15.0,
                amplitude: 0.8,
                period: 4.0,
                phase: std::f64::consts::PI,
            },
        ],
    };
    let diurnal = run_scenario(&sc);
    assert!(diurnal.completed() > 50);
    let tenants: std::collections::BTreeSet<usize> =
        diurnal.outcomes.iter().map(|o| o.tenant).collect();
    assert_eq!(tenants.len(), 2, "both tenants served");
}

#[test]
fn serving_trace_is_a_first_class_sim_result() {
    let rep = run_scenario(&smoke_scenario(45.0, 0.2, 2));
    let trace = &rep.trace;
    assert_eq!(trace.resources(), 2);
    // prefill + decode tags present, and per-replica busy time is
    // bounded by the makespan
    use hyperparallel::sim::{tags, ResourceId};
    assert!(trace.tagged_count(tags::PREFILL) > 0);
    assert!(trace.tagged_count(tags::DECODE) > 0);
    for r in 0..trace.resources() {
        let busy = trace.busy_time(ResourceId(r));
        assert!(busy > 0.0 && busy <= rep.makespan + 1e-9);
    }
}
