//! Property tests for KV page accounting: random alloc/demote/release
//! sequences against `serving::memory::PagePool` never leak or
//! double-free pages — per tier, `free + Σ per-sequence used` always
//! equals capacity (ISSUE 2 satellite); cluster-level conservation
//! holds across inter-instance KV migrations (ISSUE 3 satellite); and
//! the single-sequence `hyperoffload::kvcache::PagedKvCache` keeps its
//! page/budget/swap invariants under arbitrary append streams.

use hyperparallel::hyperoffload::kvcache::{KvCacheConfig, PagedKvCache};
use hyperparallel::serving::{migrate_pages, PagePool};
use hyperparallel::util::prop::{forall, pair_of, usize_in, vec_of, Check};
use std::collections::BTreeMap;

/// One random pool operation: (op selector, sequence selector, count).
type Op = (usize, (usize, usize));

fn ops_gen() -> hyperparallel::util::prop::Gen<Vec<Op>> {
    vec_of(
        pair_of(usize_in(0, 2), pair_of(usize_in(0, 5), usize_in(1, 8))),
        0,
        120,
    )
}

/// Reference model: explicit per-sequence maps plus free counters,
/// with the documented semantics (all-or-nothing alloc, bounded
/// demote, idempotent release).
#[derive(Debug, Default)]
struct Model {
    hbm: BTreeMap<u64, usize>,
    pool: BTreeMap<u64, usize>,
    hbm_free: usize,
    pool_free: usize,
}

impl Model {
    fn new(hbm: usize, pool: usize) -> Self {
        Self {
            hbm_free: hbm,
            pool_free: pool,
            ..Default::default()
        }
    }

    fn alloc(&mut self, seq: u64, n: usize) -> bool {
        if n > self.hbm_free {
            return false;
        }
        self.hbm_free -= n;
        *self.hbm.entry(seq).or_default() += n;
        true
    }

    fn demote(&mut self, seq: u64, n: usize) -> usize {
        let have = self.hbm.get(&seq).copied().unwrap_or(0);
        let moved = n.min(have).min(self.pool_free);
        if moved > 0 {
            *self.hbm.get_mut(&seq).unwrap() -= moved;
            *self.pool.entry(seq).or_default() += moved;
            self.hbm_free += moved;
            self.pool_free -= moved;
        }
        moved
    }

    fn release(&mut self, seq: u64) -> (usize, usize) {
        let h = self.hbm.remove(&seq).unwrap_or(0);
        let p = self.pool.remove(&seq).unwrap_or(0);
        self.hbm_free += h;
        self.pool_free += p;
        (h, p)
    }
}

const HBM_CAP: usize = 20;
const POOL_CAP: usize = 12;

#[test]
fn page_pool_never_leaks_or_double_frees() {
    forall("pagepool-conservation", 250, ops_gen(), |ops| {
        let mut pool = PagePool::new(HBM_CAP, POOL_CAP);
        let mut model = Model::new(HBM_CAP, POOL_CAP);
        for (step, &(op, (seq, n))) in ops.iter().enumerate() {
            let seq = seq as u64;
            match op {
                0 => {
                    let got = pool.try_alloc_hbm(seq, n);
                    let want = model.alloc(seq, n);
                    if got != want {
                        return Check::Fail(format!(
                            "step {step}: alloc({seq}, {n}) = {got}, model says {want}"
                        ));
                    }
                }
                1 => {
                    let got = pool.demote(seq, n);
                    let want = model.demote(seq, n);
                    if got != want {
                        return Check::Fail(format!(
                            "step {step}: demote({seq}, {n}) = {got}, model says {want}"
                        ));
                    }
                }
                _ => {
                    let got = pool.release(seq);
                    let want = model.release(seq);
                    if (got.hbm, got.pool) != want {
                        return Check::Fail(format!(
                            "step {step}: release({seq}) = {got:?}, model says {want:?}"
                        ));
                    }
                }
            }
            if let Err(e) = pool.check_conservation() {
                return Check::Fail(format!("step {step}: {e}"));
            }
            if pool.hbm_free() != model.hbm_free || pool.pool_free() != model.pool_free {
                return Check::Fail(format!(
                    "step {step}: free counters diverge: ({}, {}) vs ({}, {})",
                    pool.hbm_free(),
                    pool.pool_free(),
                    model.hbm_free,
                    model.pool_free
                ));
            }
        }
        // drain everything: a full release cycle restores both tiers
        for seq in 0..6u64 {
            pool.release(seq);
        }
        if pool.hbm_free() != HBM_CAP || pool.pool_free() != POOL_CAP {
            return Check::Fail(format!(
                "leak after full drain: hbm {}/{HBM_CAP}, pool {}/{POOL_CAP}",
                pool.hbm_free(),
                pool.pool_free()
            ));
        }
        Check::Pass
    });
}

#[test]
fn double_release_frees_nothing() {
    forall(
        "pagepool-double-free",
        150,
        pair_of(usize_in(1, HBM_CAP), usize_in(0, 5)),
        |&(n, seq)| {
            let seq = seq as u64;
            let mut pool = PagePool::new(HBM_CAP, POOL_CAP);
            assert!(pool.try_alloc_hbm(seq, n));
            pool.demote(seq, n / 2);
            let first = pool.release(seq);
            if first.total() != n {
                return Check::Fail(format!("first release freed {} of {n}", first.total()));
            }
            let second = pool.release(seq);
            if second.total() != 0 {
                return Check::Fail(format!(
                    "double release freed {} pages",
                    second.total()
                ));
            }
            if pool.hbm_free() != HBM_CAP || pool.pool_free() != POOL_CAP {
                return Check::Fail("double release corrupted the free counters".into());
            }
            Check::Pass
        },
    );
}

/// One random cluster op over a fleet of instance pools:
/// (op selector, (sequence selector, (page count, target pool))).
type ClusterOp = (usize, (usize, (usize, usize)));

const FLEET: usize = 3;
const INST_CAP: usize = 16;

fn cluster_ops_gen() -> hyperparallel::util::prop::Gen<Vec<ClusterOp>> {
    vec_of(
        pair_of(
            usize_in(0, 3),
            pair_of(usize_in(0, 7), pair_of(usize_in(1, 6), usize_in(0, FLEET - 1))),
        ),
        0,
        160,
    )
}

/// Cluster-level conservation (ISSUE 3 satellite): random
/// alloc/grow/release/**migrate** sequences over a fleet of instance
/// pools never leak or double-free a page. A sequence's pages live in
/// exactly one instance at a time (the cluster's custody rule:
/// allocate at the destination, then release the source), every pool
/// individually conserves `free + Σ ledger = capacity`, and the
/// fleet-wide used total always equals the model's.
#[test]
fn kv_pages_conserved_across_instance_migrations() {
    forall("cluster-migration-conservation", 250, cluster_ops_gen(), |ops| {
        let mut pools: Vec<PagePool> = (0..FLEET).map(|_| PagePool::new(INST_CAP, 0)).collect();
        // model: seq -> (owner instance, pages held)
        let mut owner: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
        for (step, &(op, (seq, (n, target)))) in ops.iter().enumerate() {
            let seq = seq as u64;
            match op {
                // allocate/grow n pages wherever the sequence lives
                // (fresh sequences are admitted at `target`)
                0 => {
                    let at = owner.get(&seq).map(|&(o, _)| o).unwrap_or(target);
                    let fits = n <= pools[at].hbm_free();
                    let got = pools[at].try_alloc_hbm(seq, n);
                    if got != fits {
                        return Check::Fail(format!(
                            "step {step}: alloc({seq}, {n}) = {got}, space says {fits}"
                        ));
                    }
                    if got {
                        owner.entry(seq).or_insert((at, 0)).1 += n;
                    }
                }
                // release everything the sequence holds
                1 => match owner.remove(&seq) {
                    Some((o, pages)) => {
                        let f = pools[o].release(seq);
                        if f.total() != pages {
                            return Check::Fail(format!(
                                "step {step}: release({seq}) freed {} of {pages}",
                                f.total()
                            ));
                        }
                    }
                    None => {
                        if pools[target].release(seq).total() != 0 {
                            return Check::Fail(format!(
                                "step {step}: released pages for an unknown sequence"
                            ));
                        }
                    }
                },
                // migrate the whole sequence to `target`
                _ => {
                    let (src, pages) = match owner.get(&seq) {
                        Some(&(o, p)) => (o, p),
                        None => {
                            // migrating an unknown sequence moves nothing
                            let (a, b) = split_pair(&mut pools, target, (target + 1) % FLEET);
                            if migrate_pages(a, b, seq) {
                                return Check::Fail(format!(
                                    "step {step}: migrated a sequence that holds nothing"
                                ));
                            }
                            continue;
                        }
                    };
                    if src == target {
                        continue;
                    }
                    let expect = pools[target].hbm_free() >= pages;
                    let (a, b) = split_pair(&mut pools, src, target);
                    let moved = migrate_pages(a, b, seq);
                    if moved != expect {
                        return Check::Fail(format!(
                            "step {step}: migrate({seq}) = {moved}, space says {expect}"
                        ));
                    }
                    if moved {
                        owner.insert(seq, (target, pages));
                        if pools[src].seq_pages(seq).total() != 0 {
                            return Check::Fail(format!(
                                "step {step}: source still holds pages after migration"
                            ));
                        }
                        if pools[target].seq_pages(seq).total() != pages {
                            return Check::Fail(format!(
                                "step {step}: destination holds {} of {pages}",
                                pools[target].seq_pages(seq).total()
                            ));
                        }
                    }
                }
            }
            // fleet-wide invariants after every op
            for (i, p) in pools.iter().enumerate() {
                if let Err(e) = p.check_conservation() {
                    return Check::Fail(format!("step {step}: pool {i}: {e}"));
                }
            }
            let model_used: usize = owner.values().map(|&(_, p)| p).sum();
            let pool_used: usize = pools.iter().map(|p| p.hbm_used()).sum();
            if model_used != pool_used {
                return Check::Fail(format!(
                    "step {step}: fleet used {pool_used} != model {model_used}"
                ));
            }
            for (&seq, &(o, pages)) in &owner {
                for (i, p) in pools.iter().enumerate() {
                    let held = p.seq_pages(seq).total();
                    let want = if i == o { pages } else { 0 };
                    if held != want {
                        return Check::Fail(format!(
                            "step {step}: seq {seq} holds {held} in pool {i}, want {want}"
                        ));
                    }
                }
            }
        }
        // drain: releasing every sequence restores every pool
        for seq in 0..7u64 {
            if let Some((o, _)) = owner.remove(&seq) {
                pools[o].release(seq);
            }
        }
        for (i, p) in pools.iter().enumerate() {
            if p.hbm_free() != INST_CAP {
                return Check::Fail(format!("pool {i} leaked: free {}", p.hbm_free()));
            }
        }
        Check::Pass
    });
}

/// Two distinct mutable pool references out of the fleet.
fn split_pair(pools: &mut [PagePool], a: usize, b: usize) -> (&mut PagePool, &mut PagePool) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = pools.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = pools.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Spec for the single-sequence cache: (hbm token capacity beyond the
/// weights, offload frac selector, tokens to append).
type CacheSpec = (usize, (usize, usize));

fn cache_gen() -> hyperparallel::util::prop::Gen<CacheSpec> {
    pair_of(usize_in(0, 400), pair_of(usize_in(0, 2), usize_in(0, 800)))
}

#[test]
fn paged_kvcache_pages_budget_and_swaps_consistent() {
    forall("kvcache-invariants", 120, cache_gen(), |&(cap_tokens, (frac_sel, appends))| {
        let cfg = KvCacheConfig {
            kv_bytes_per_token: 1024,
            tokens_per_page: 16,
            weight_bytes: 1 << 20,
            hbm_usable: (1 << 20) + cap_tokens as u64 * 1024,
            hbm_bw: 1e12,
            pool_bw: 100e9,
            attn_tokens_per_s: 40e6,
        };
        let frac = [0.0, 0.25, 0.5][frac_sel];
        let budget = cfg.kv_token_capacity(frac) / cfg.tokens_per_page;
        let mut cache = PagedKvCache::new(cfg.clone(), frac);
        if cache.hbm_page_budget() != budget {
            return Check::Fail("budget mismatch with planner math".into());
        }
        for step in 1..=appends {
            cache.append_token();
            if cache.tokens() != step {
                return Check::Fail(format!("token count {} != {step}", cache.tokens()));
            }
            let want_pages = step.div_ceil(cfg.tokens_per_page);
            if cache.pages() != want_pages {
                return Check::Fail(format!(
                    "pages {} != ceil({step}/{}) = {want_pages}",
                    cache.pages(),
                    cfg.tokens_per_page
                ));
            }
            // the HBM residency never exceeds the budget (one page of
            // slack when the budget is zero: the hot tail stays HBM)
            if cache.hbm_pages() > budget.max(1) {
                return Check::Fail(format!(
                    "hbm pages {} exceed budget {budget}",
                    cache.hbm_pages()
                ));
            }
            // conservation: every page is in exactly one tier, and the
            // swap counter accounts for every pool-resident page
            let pool_pages = cache.pages() - cache.hbm_pages();
            if cache.pages_swapped_out != pool_pages as u64 {
                return Check::Fail(format!(
                    "swap counter {} != pool pages {pool_pages}",
                    cache.pages_swapped_out
                ));
            }
            let (hbm_bytes, pool_bytes) = cache.bytes_by_home();
            if hbm_bytes + pool_bytes != cache.pages() as u64 * cfg.page_bytes() {
                return Check::Fail("bytes_by_home loses pages".into());
            }
        }
        Check::Pass
    });
}
