//! `HP_SWEEP_THREADS` override behavior (ISSUE 3 satellite).
//!
//! These assertions mutate a process-global environment variable, and
//! `sim::sweep::worker_count` reads it on every `parallel_map` call —
//! so they cannot live in the library's unit-test binary, where other
//! tests sweep concurrently (concurrent setenv/getenv is undefined
//! behavior in glibc). This integration binary holds exactly one
//! test, so nothing else can observe the transient values.

use hyperparallel::sim::parallel_map;
use hyperparallel::sim::sweep::worker_count;

#[test]
fn env_override_clamps_and_trims() {
    let cases: [(&str, usize); 6] = [
        ("7", 7),     // plain value honored
        (" 7 ", 7),   // regression: untrimmed values fell back to hw
        ("7\n", 7),   // trailing newline from `export X=$(...)`
        ("0", 1),     // zero clamps to the sequential path
        ("1", 1),
        ("9999", 64), // capped by the item count
    ];
    for (val, want) in cases {
        std::env::set_var("HP_SWEEP_THREADS", val);
        assert_eq!(worker_count(64), want, "HP_SWEEP_THREADS={val:?}");
    }
    // unparsable values fall back to hardware parallelism, >= 1
    for junk in ["", "zero", "-3", "1.5"] {
        std::env::set_var("HP_SWEEP_THREADS", junk);
        assert!(worker_count(64) >= 1, "HP_SWEEP_THREADS={junk:?}");
    }
    // a sweep under an override still produces ordered results
    std::env::set_var("HP_SWEEP_THREADS", "2");
    let items: Vec<usize> = (0..50).collect();
    let out = parallel_map(&items, |&x| x + 1);
    std::env::remove_var("HP_SWEEP_THREADS");
    assert_eq!(out, (1..=50).collect::<Vec<_>>());
}
