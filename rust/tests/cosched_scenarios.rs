//! Co-scheduling scenario tests (ISSUE 5 acceptance): the
//! paper-shaped supernode-vs-legacy crossover for running training and
//! serving as two tenants of one device pool.
//!
//! The checked-in scenario (seed 42, mirrored + calibrated by
//! tools/cosched_simcheck.py): PR 4's diurnal two-tenant serving
//! workload over a 32-device pool. On the supernode fabric the
//! broker-mediated co-schedule holds the 0.5 s p99 TTFT serving SLO
//! while completing ≥1.4× the training steps of a static half/half
//! partition (mirror: 82 vs 54 steps, 1.52×, serving p99 ≈ 0.37 s).
//! On legacy RoCE the advantage collapses (mirror: 1.04×): each of the
//! ~40 lease reconfigurations moves 96 GiB of sharded state over ~1/15
//! the bandwidth (~12.8 s total vs ~0.9 s on the supernode), eating
//! the harvested trough time — and the warm-up lag blows the serving
//! SLO anyway, exactly as PR 4's elastic scenario showed.

use hyperparallel::faults::{DeviceFail, LinkDegrade, RetryPolicy};
use hyperparallel::hypermpmd::coschedule::{
    assert_tenant_isolation, cosched_comparison, cosched_scenario, cosched_slo, run_cosched,
    CoschedMode, COSCHED_POOL_DEVICES, COSCHED_RESERVE, COSCHED_STATIC_SERVING,
};
use hyperparallel::serving::{
    ArrivalProcess, ClusterFabric, LengthDist, WorkloadConfig, AUTOSCALE_MEAN_RATE,
};
use hyperparallel::sim::tags;
use hyperparallel::supernode::{DeviceId, LinkTier};

#[test]
fn cosched_beats_static_partition_on_supernode_at_the_serving_slo() {
    let slo = cosched_slo();
    let sn = cosched_comparison(ClusterFabric::Supernode);

    // the serving tenant held its SLO under co-scheduling...
    let cop = sn
        .cosched
        .serving
        .operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert_eq!(cop.rejected, 0, "co-scheduling must not shed serving load");
    assert!(
        cop.attains_slo,
        "co-scheduled serving must hold the SLO: p99 ttft {}",
        cop.p99_ttft
    );
    // ...and so did the static half (the comparison is at identical SLO)
    let sop = sn
        .static_partition
        .serving
        .operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert!(sop.attains_slo, "static half must attain: {}", sop.p99_ttft);

    // the headline: ≥1.4× the training steps of the static partition
    let gain = sn.step_gain();
    assert!(
        gain >= 1.40,
        "co-scheduling must harvest >=1.4x training steps on the supernode \
         fabric: {gain:.3} ({} vs {})",
        sn.cosched.train.steps_by_deadline,
        sn.static_partition.train.steps_by_deadline
    );

    // the harvest is real elasticity, not a bigger static share: the
    // trainer's lease breathed with the diurnal serving swing
    assert!(sn.cosched.train.reshards >= 10, "{}", sn.cosched.train.reshards);
    assert!(
        sn.cosched.train.peak_devices > COSCHED_POOL_DEVICES - COSCHED_STATIC_SERVING,
        "trough harvest must exceed the static half: peak {}",
        sn.cosched.train.peak_devices
    );
    assert_eq!(sn.static_partition.train.reshards, 0);
    assert_eq!(
        sn.static_partition.train.peak_devices,
        COSCHED_POOL_DEVICES - COSCHED_STATIC_SERVING
    );
    // both tenants left their marks in the indexed traces
    assert!(sn.cosched.train.trace.tagged_count(tags::TRAIN_STEP) > 0);
    assert!(
        sn.cosched.train.trace.tagged_count(tags::RESHARD) as u64 >= sn.cosched.train.reshards,
        "every reshard spans its union group"
    );
    assert!(sn.cosched.serving.scale_ups >= 5);
    assert!(sn.cosched.serving.scale_downs >= 5);
}

#[test]
fn the_advantage_collapses_on_legacy_roce() {
    let sn = cosched_comparison(ClusterFabric::Supernode);
    let lg = cosched_comparison(ClusterFabric::Legacy);

    // reshard cost eats the harvest: barely better than (or worse
    // than) the static partition
    let gain_lg = lg.step_gain();
    let gain_sn = sn.step_gain();
    assert!(
        gain_lg <= 1.10,
        "legacy co-scheduling must not beat static by more than 10%: {gain_lg:.3}"
    );
    assert!(
        gain_sn - gain_lg >= 0.25,
        "the fabric must decide the crossover: supernode {gain_sn:.3} vs legacy {gain_lg:.3}"
    );
    assert!(
        lg.cosched.train.reshard_seconds > 10.0 * sn.cosched.train.reshard_seconds,
        "legacy resharding must dwarf supernode resharding: {} vs {}",
        lg.cosched.train.reshard_seconds,
        sn.cosched.train.reshard_seconds
    );

    // the static halves never touch the broker or the fabric: their
    // training side is fabric-independent up to the gradient sync, and
    // their serving side is bit-identical across fabrics (colocated
    // clusters never migrate)
    assert_eq!(lg.static_partition.train.reshards, 0);
    assert_eq!(
        sn.static_partition
            .serving
            .serving
            .ttft_pct(99.0)
            .to_bits(),
        lg.static_partition
            .serving
            .serving
            .ttft_pct(99.0)
            .to_bits(),
        "static serving halves must be bit-identical across fabrics"
    );

    // and the serving SLO is blown on legacy too (PR 4's warm-up term)
    let slo = cosched_slo();
    let lop = lg.cosched.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert!(
        lop.p99_ttft > slo.ttft_p99,
        "legacy co-scheduled serving must blow the TTFT SLO: {}",
        lop.p99_ttft
    );
}

// ---- ISSUE 5 satellite: broker conservation property ------------------

/// Property: across reserve sizes, both modes, and with/without the
/// ISSUE 6 fault plan layered on, every device is leased to exactly
/// one tenant at any instant, and every lease is back at the broker
/// (or held by a live serving instance, or revoked by a device fail)
/// at drain. `run_cosched` itself asserts the set-partition invariant;
/// this test adds the interval-overlap view and the ledger totals.
#[test]
fn broker_conservation_across_reserve_and_mode_grid() {
    for mode in [CoschedMode::Cosched, CoschedMode::StaticPartition] {
        for reserve in [0usize, 1, 2] {
            for seed in [7u64, 11] {
                for faulted in [false, true] {
                    let mut cfg = cosched_scenario(ClusterFabric::Supernode, mode);
                    cfg.reserve = reserve;
                    cfg.horizon = 6.0;
                    cfg.train.train_until = 6.0;
                    cfg.workload = WorkloadConfig {
                        arrival: ArrivalProcess::Poisson { rate: 30.0 },
                        prompt: LengthDist::Uniform { lo: 200, hi: 600 },
                        output: LengthDist::Uniform { lo: 16, hi: 48 },
                        seed,
                    };
                    if faulted {
                        cfg.cluster.faults.link_windows.push(LinkDegrade {
                            tier: LinkTier::Rack,
                            start: 1.0,
                            end: 3.0,
                            bandwidth_scale: 0.05,
                            latency_scale: 5.0,
                        });
                        cfg.cluster
                            .faults
                            .device_fails
                            .push(DeviceFail { time: 2.0, ordinal: 1 });
                        cfg.cluster.retry = Some(RetryPolicy::degraded_fabric());
                    }
                    let rep = run_cosched(&cfg);
                    let cell =
                        format!("mode={mode:?} reserve={reserve} seed={seed} faulted={faulted}");
                    assert_tenant_isolation(&rep);
                    // ledger: free + held-by-serving + crashed + failed
                    // covers the pool exactly (no crashes are injected
                    // here, so that term is always empty)
                    let accounted = rep.broker.free_at_end.len()
                        + rep.serving.held_devices_at_end.len()
                        + rep.serving.crashed_devices.len()
                        + rep.broker.failed_at_end.len();
                    assert_eq!(accounted, COSCHED_POOL_DEVICES, "{cell}");
                    assert!(rep.serving.crashed_devices.is_empty(), "{cell}");
                    assert!(rep.broker.failed_at_end.len() <= 1, "{cell}");
                    assert!(
                        rep.train.steps_lost <= rep.train.device_fails,
                        "{cell}: checkpoint-restore loses at most a step per fail"
                    );
                    // nothing lost on the serving side either
                    let submitted = cfg.workload.generate(cfg.horizon).len();
                    assert_eq!(
                        rep.serving.serving.outcomes.len() + rep.serving.serving.rejected as usize,
                        submitted,
                        "{cell}"
                    );
                    if mode == CoschedMode::StaticPartition {
                        assert_eq!(rep.broker.lease_misses, 0, "{cell}");
                    }
                }
            }
        }
    }
}

/// The broker's reserve is what hides preemption latency: with no
/// reserve every serving scale-up waits for a training step boundary
/// plus a reshard, so lease misses strictly increase.
#[test]
fn reserve_headroom_absorbs_serving_scale_ups() {
    let run_with_reserve = |reserve: usize| {
        let mut cfg = cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched);
        cfg.reserve = reserve;
        cfg.horizon = 12.0;
        cfg.train.train_until = 12.0;
        run_cosched(&cfg)
    };
    let none = run_with_reserve(0);
    let some = run_with_reserve(COSCHED_RESERVE);
    assert!(
        none.broker.lease_misses > some.broker.lease_misses,
        "reserve must absorb scale-up bursts: {} vs {}",
        none.broker.lease_misses,
        some.broker.lease_misses
    );
}

/// Devices are physical: the trainer's trace devices and the serving
/// instances' devices all come from the same 32-device spread, and
/// none appears twice in either tenant's resource table.
#[test]
fn trace_resources_map_to_distinct_pool_devices() {
    let mut cfg = cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched);
    cfg.horizon = 6.0;
    cfg.train.train_until = 6.0;
    let rep = run_cosched(&cfg);
    let distinct: std::collections::BTreeSet<DeviceId> =
        rep.train.trace_devices.iter().copied().collect();
    assert_eq!(distinct.len(), rep.train.trace_devices.len());
    assert_eq!(rep.train.trace.resources(), rep.train.trace_devices.len());
    assert!(rep.train.trace_devices.len() <= COSCHED_POOL_DEVICES);
}
