//! Property tests on HyperShard layout derivation (Fig 6 semantics).

use hyperparallel::hypershard::{Layout, MapDim};
use hyperparallel::util::prop::{forall, vec_of, usize_in, Check};
use hyperparallel::util::rng::Rng;

const AXES: [&str; 4] = ["a", "b", "c", "d"];

fn random_layout(dims: &[usize]) -> Layout {
    Layout::new(dims, &AXES[..dims.len()]).unwrap()
}

/// num_shards · replication == device_count for every valid tensor_map.
#[test]
fn prop_shards_times_replication_is_device_count() {
    forall(
        "shards-x-replication",
        200,
        vec_of(usize_in(1, 4), 1, 4),
        |dims| {
            let layout = random_layout(dims);
            let mut rng = Rng::new(dims.iter().sum::<usize>() as u64);
            // random tensor_map: each axis used at most once
            let mut available: Vec<usize> = (0..dims.len()).collect();
            rng.shuffle(&mut available);
            let rank = rng.range(1, 4);
            let mut map = Vec::new();
            for _ in 0..rank {
                if !available.is_empty() && rng.chance(0.7) {
                    let ax = available.pop().unwrap();
                    map.push(MapDim::Axis(AXES[ax]));
                } else {
                    map.push(MapDim::None);
                }
            }
            let spec = match layout.apply(&map) {
                Ok(s) => s,
                Err(e) => return Check::Fail(format!("apply failed: {e}")),
            };
            Check::from_bool(
                spec.num_shards * spec.replication == layout.device_count(),
                &format!(
                    "{} shards x {} replication != {} devices",
                    spec.num_shards,
                    spec.replication,
                    layout.device_count()
                ),
            )
        },
    );
}

/// Placement assigns every device a shard index within range, and each
/// shard is held by exactly `replication` devices.
#[test]
fn prop_placement_is_balanced() {
    forall(
        "placement-balanced",
        150,
        vec_of(usize_in(1, 4), 2, 3),
        |dims| {
            let layout = random_layout(dims);
            // shard dim0 on the first axis; replicate the rest
            let spec = layout.apply(&[MapDim::Axis(AXES[0]), MapDim::None]).unwrap();
            let placement = layout.placement(&spec);
            let mut counts = std::collections::BTreeMap::new();
            for shard in &placement {
                if shard[0] >= spec.shard_counts[0] || shard[1] != 0 {
                    return Check::Fail(format!("shard index out of range: {shard:?}"));
                }
                *counts.entry(shard.clone()).or_insert(0usize) += 1;
            }
            Check::from_bool(
                counts.values().all(|&c| c == spec.replication),
                &format!("unbalanced placement: {counts:?}"),
            )
        },
    );
}

/// Shard shapes tile the global tensor exactly.
#[test]
fn prop_shard_shapes_tile_global() {
    forall(
        "shard-shapes-tile",
        150,
        vec_of(usize_in(1, 4), 2, 2),
        |dims| {
            let layout = random_layout(dims);
            let spec = layout
                .apply(&[MapDim::Axis(AXES[0]), MapDim::Axis(AXES[1])])
                .unwrap();
            // pick a global shape divisible by the shard counts
            let global = [spec.shard_counts[0] * 6, spec.shard_counts[1] * 5];
            let shard = spec.shard_shape(&global);
            Check::from_bool(
                shard[0] * spec.shard_counts[0] == global[0]
                    && shard[1] * spec.shard_counts[1] == global[1],
                &format!("{shard:?} x {:?} != {global:?}", spec.shard_counts),
            )
        },
    );
}

/// rank_of and coords_of are inverse bijections for random matrices.
#[test]
fn prop_rank_coords_bijection() {
    forall(
        "rank-coords-bijection",
        100,
        vec_of(usize_in(1, 5), 1, 4),
        |dims| {
            let layout = random_layout(dims);
            let n = layout.device_count();
            let mut seen = vec![false; n];
            for r in 0..n {
                let c = layout.coords_of(r);
                let back = layout.rank_of(&c);
                if back != r {
                    return Check::Fail(format!("rank {r} -> {c:?} -> {back}"));
                }
                seen[r] = true;
            }
            Check::from_bool(seen.iter().all(|&s| s), "not all ranks covered")
        },
    );
}
