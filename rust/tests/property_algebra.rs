//! Property suite for the strategy algebra (ISSUE 10 satellite):
//! under randomly generated well-formed expressions, normalization
//! preserves the device count and the evaluated cost is bit-identical
//! to pricing the hand-built [`ParallelStrategy`] directly; malformed
//! terms (zero dims, degree overflow, unknown or over-subscribed
//! pools) come back as `Err`, never a panic.

use hyperparallel::config::ModelDesc;
use hyperparallel::hypershard::{
    evaluate_expr, lower_fleet, normalize, try_evaluate, PlannerConfig, StrategyExpr,
};
use hyperparallel::supernode::{DeviceSpec, Fabric, Fleet, Geometry, Topology};
use hyperparallel::util::prop::{forall, Check, Gen};
use hyperparallel::util::rng::Rng;

/// A random well-formed expression: atoms with small degrees, `Seq`
/// and `Nest` combinators up to the given depth, no `OnPool` (the
/// pool-constrained terms get their own fleet-path cases below).
fn random_expr(rng: &mut Rng, depth: usize) -> StrategyExpr {
    use StrategyExpr::*;
    let pick = if depth == 0 {
        rng.range(0, 8)
    } else {
        rng.range(0, 10)
    };
    match pick {
        0 => Dp(rng.range(1, 4)),
        1 => Tp(rng.range(1, 4)),
        2 => Pp(rng.range(1, 4)),
        3 => Ep(rng.range(1, 4)),
        4 => Cp(rng.range(1, 4)),
        5 => Sp,
        6 => Fsdp,
        7 => Mpmd,
        8 => {
            let n = rng.range(0, 4);
            Seq((0..n).map(|_| random_expr(rng, depth - 1)).collect())
        }
        _ => StrategyExpr::nest(random_expr(rng, depth - 1), random_expr(rng, depth - 1)),
    }
}

/// Structural shrinker: children of a combinator, elements dropped
/// from a `Seq`, degrees decremented toward 1 — every step is a
/// strictly smaller term, so `forall`'s greedy shrink terminates.
fn shrink_expr(e: &StrategyExpr) -> Vec<StrategyExpr> {
    use StrategyExpr::*;
    match e {
        Dp(n) if *n > 1 => vec![Dp(n - 1)],
        Tp(n) if *n > 1 => vec![Tp(n - 1)],
        Pp(n) if *n > 1 => vec![Pp(n - 1)],
        Ep(n) if *n > 1 => vec![Ep(n - 1)],
        Cp(n) if *n > 1 => vec![Cp(n - 1)],
        Seq(xs) => {
            let mut out: Vec<StrategyExpr> = xs.clone();
            for i in 0..xs.len() {
                let mut fewer = xs.clone();
                fewer.remove(i);
                out.push(Seq(fewer));
            }
            out
        }
        Nest(a, b) => vec![(**a).clone(), (**b).clone()],
        _ => Vec::new(),
    }
}

fn expr_gen(depth: usize) -> Gen<StrategyExpr> {
    Gen::new(move |r| random_expr(r, depth), shrink_expr)
}

/// The product a well-formed term must normalize to: sized atoms
/// multiply (`Ep` is DeepSeek-style EP ⊆ DP and does not), flags and
/// the empty `Seq` are the identity.
fn expected_devices(e: &StrategyExpr) -> u128 {
    use StrategyExpr::*;
    match e {
        Dp(n) | Tp(n) | Pp(n) | Cp(n) => *n as u128,
        Ep(_) | Sp | Fsdp | Mpmd => 1,
        Seq(xs) => xs.iter().map(expected_devices).product(),
        Nest(a, b) => expected_devices(a) * expected_devices(b),
        OnPool(_, inner) => expected_devices(inner),
    }
}

#[test]
fn normalization_preserves_device_count() {
    forall("algebra-device-count", 400, expr_gen(3), |e| {
        let nf = match normalize(e) {
            Ok(nf) => nf,
            Err(msg) => return Check::Fail(format!("well-formed term rejected: {msg}")),
        };
        let got = nf.strategy.device_count() as u128;
        let want = expected_devices(e);
        Check::from_bool(
            got == want,
            &format!("device_count {got} != atom product {want}"),
        )
    });
}

#[test]
fn seq_and_nest_share_a_normal_form() {
    let gen = Gen::new(
        |r| (random_expr(r, 2), random_expr(r, 2)),
        |(a, b)| {
            let mut out = Vec::new();
            for x in shrink_expr(a) {
                out.push((x, b.clone()));
            }
            for y in shrink_expr(b) {
                out.push((a.clone(), y));
            }
            out
        },
    );
    forall("algebra-seq-nest-law", 400, gen, |(a, b)| {
        let seq = normalize(&StrategyExpr::Seq(vec![a.clone(), b.clone()]));
        let nest = normalize(&StrategyExpr::nest(a.clone(), b.clone()));
        Check::from_bool(
            seq == nest,
            "Seq[a, b] and a(b) disagree on the normal form",
        )
    });
}

#[test]
fn evaluated_cost_matches_hand_built_strategy() {
    let model = ModelDesc::tiny_moe();
    let cfg = PlannerConfig::default();
    forall("algebra-cost-parity", 300, expr_gen(3), |e| {
        let nf = normalize(e).expect("generator only emits well-formed terms");
        let n = nf.strategy.device_count();
        if n > 128 {
            // keep the per-case device table small; the count property
            // above already covers the large products
            return Check::Pass;
        }
        // a topology sized exactly to the term, so the grid covers it
        let topo = Topology::new(
            Geometry {
                racks: 1,
                boards_per_rack: 1,
                dies_per_board: n,
            },
            Fabric::supernode(),
            DeviceSpec::ascend_910c(),
        );
        let via_expr = match evaluate_expr(&model, &topo, e, &cfg) {
            Ok(c) => c,
            Err(msg) => return Check::Fail(format!("expr failed to lower: {msg}")),
        };
        let direct = try_evaluate(&model, &topo, &nf.strategy, &cfg)
            .expect("normal form covers the topology by construction");
        let same = via_expr.step_time.to_bits() == direct.step_time.to_bits()
            && via_expr.state_bytes_per_device == direct.state_bytes_per_device
            && via_expr.fits_hbm == direct.fits_hbm;
        Check::from_bool(same, "expr cost differs from the hand-built strategy cost")
    });
}

#[test]
fn zero_dims_error_anywhere_in_a_term() {
    forall("algebra-zero-dim", 300, expr_gen(2), |e| {
        // graft a malformed atom into an otherwise well-formed tree:
        // the whole term must be rejected, not silently repaired
        let poisoned = StrategyExpr::Seq(vec![e.clone(), StrategyExpr::Cp(0)]);
        let nested = StrategyExpr::nest(StrategyExpr::Dp(0), e.clone());
        Check::from_bool(
            normalize(&poisoned).is_err() && normalize(&nested).is_err(),
            "a zero-degree atom normalized instead of erroring",
        )
    });
}

#[test]
fn malformed_terms_error_instead_of_panicking() {
    // degree overflow: the product of two huge dims exceeds usize
    let big = usize::MAX / 2;
    let overflow = StrategyExpr::Seq(vec![StrategyExpr::Dp(big), StrategyExpr::Dp(4)]);
    assert!(normalize(&overflow).is_err(), "dp overflow accepted");
    // ...and a device-count overflow across *different* dims
    let cross = StrategyExpr::Seq(vec![StrategyExpr::Dp(big), StrategyExpr::Tp(4)]);
    assert!(normalize(&cross).is_err(), "device-count overflow accepted");

    // empty pool pattern and conflicting pool placements
    assert!(normalize(&StrategyExpr::on_pool("", StrategyExpr::Dp(2))).is_err());
    let conflict = StrategyExpr::on_pool(
        "910c",
        StrategyExpr::on_pool("910b", StrategyExpr::Dp(2)),
    );
    assert!(normalize(&conflict).is_err(), "conflicting pools accepted");

    let fleet = Fleet::mixed_generations();
    let cfg = PlannerConfig::default();
    // unknown pool name
    let unknown = StrategyExpr::on_pool("no-such-pool", StrategyExpr::Dp(8));
    assert!(lower_fleet(&unknown, &fleet, &cfg).is_err(), "unknown pool");
    // over-subscribing one pool (32 devices per pool in this fleet)
    let over = StrategyExpr::on_pool("910c", StrategyExpr::Dp(33));
    assert!(lower_fleet(&over, &fleet, &cfg).is_err(), "oversubscribed");
    // ...and the whole fleet (64 devices total)
    let over_fleet = StrategyExpr::Dp(65);
    assert!(lower_fleet(&over_fleet, &fleet, &cfg).is_err(), "over fleet");
}
