//! Fleet property suite (ISSUE 9 satellites).
//!
//! Three families of guarantees around the fleet generalization:
//!
//! 1. **Degenerate bit-identity.** Wrapping a scenario's topology in a
//!    one-pool uniform [`Fleet`] must change *nothing*: every PR 2–8
//!    seed-42 preset (crossover, autoscale, crash-recovery, agentic,
//!    co-scheduled, faulted, chaos) reruns with `Fleet::single` and
//!    every `summary_kv` row — and the broker ledger — compares equal
//!    to the bit. This is the contract that lets the fleet code ride
//!    in every path without perturbing seven PRs of calibrated
//!    numbers: uniform speeds are exactly 1.0 (`x / x`), single-pool
//!    collectives delegate to the topology pricer, and the serving
//!    cluster's `multi_pool_fleet` guard turns the whole feature off.
//!
//! 2. **Partition conservation.** Compute-proportional partitions
//!    (`hypershard::heterogeneous`) conserve the total item count,
//!    never exceed a device's HBM cap, and reproduce count-based
//!    splitting on uniform groups — fuzzed over seeded random weight
//!    vectors and checked on both heterogeneity-battery fleets.
//!
//! 3. **Chaos × heterogeneity.** The PR 6 chaos grid extended with a
//!    heterogeneous-pool dimension: seeded `random_fleet_plan`
//!    schedules (which can degrade the inter-supernode link itself)
//!    run against the mixed-generation and slow-rack fleet scenarios,
//!    and the global invariants hold under every one — request
//!    conservation, ≤-one-step-lost-per-fail, tenant isolation, and
//!    the lease ledger staying a *partition* (each fleet device in
//!    exactly one terminal state). Mirrored by the fleet chaos suite
//!    in `tools/cosched_simcheck.py`.

use std::collections::BTreeSet;

use hyperparallel::faults::chaos::{random_fleet_plan, CHAOS_HORIZON};
use hyperparallel::faults::RetryPolicy;
use hyperparallel::hypermpmd::coschedule::{
    assert_tenant_isolation, chaos_cosched_scenario, cosched_scenario, fault_cosched_scenario,
    fleet_cosched_scenario, run_cosched, CoschedConfig, CoschedMode, FleetScenario,
    FLEET_SLOW_RACK_DERATE,
};
use hyperparallel::hypershard::{
    compute_weights, memory_caps, partition_for_group, proportional_partition,
};
use hyperparallel::serving::{
    agentic_scenario, autoscale_crash_scenario, autoscale_scenario, crossover_scenario,
    run_agentic_scenario, run_cluster_scenario, ClusterFabric, ClusterMode, ClusterScenario,
};
use hyperparallel::supernode::Fleet;
use hyperparallel::util::rng::Rng;

// ---- 1. degenerate bit-identity ---------------------------------------

/// Compare two `summary_kv` emissions to the bit: same keys, same
/// order, bitwise-equal values.
fn assert_rows_identical(label: &str, base: &[(String, f64)], fleet: &[(String, f64)]) {
    assert_eq!(base.len(), fleet.len(), "{label}: row count drifted");
    for ((kb, vb), (kf, vf)) in base.iter().zip(fleet) {
        assert_eq!(kb, kf, "{label}: key order drifted");
        assert_eq!(
            vb.to_bits(),
            vf.to_bits(),
            "{label}: {kb} perturbed by the uniform fleet ({vb} vs {vf})"
        );
    }
}

/// Run a serving preset bare and wrapped in a one-pool fleet; the
/// reports must match to the bit (both placement-policy settings —
/// the flag is defined to be inert without a multi-pool fleet).
fn assert_serving_degenerate(label: &str, sc: &ClusterScenario) {
    let base = run_cluster_scenario(sc);
    for aware in [true, false] {
        let mut wrapped = sc.clone();
        wrapped.cluster.fleet = Some(Fleet::single(sc.cluster.topology.clone()));
        wrapped.cluster.fleet_aware_placement = aware;
        let rep = run_cluster_scenario(&wrapped);
        assert_rows_identical(
            &format!("{label}/aware={aware}"),
            &base.summary_kv(),
            &rep.summary_kv(),
        );
    }
}

#[test]
fn uniform_fleet_is_bit_identical_on_crossover_presets() {
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        for mode in [ClusterMode::Colocated, ClusterMode::Disaggregated] {
            assert_serving_degenerate(
                &format!("crossover/{fabric:?}/{mode:?}"),
                &crossover_scenario(fabric, mode),
            );
        }
    }
}

#[test]
fn uniform_fleet_is_bit_identical_on_autoscale_presets() {
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        for elastic in [true, false] {
            assert_serving_degenerate(
                &format!("autoscale/{fabric:?}/elastic={elastic}"),
                &autoscale_scenario(fabric, elastic),
            );
        }
        assert_serving_degenerate(
            &format!("autoscale-crash/{fabric:?}"),
            &autoscale_crash_scenario(fabric),
        );
    }
}

#[test]
fn uniform_fleet_is_bit_identical_on_agentic_presets() {
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        for cache_aware in [true, false] {
            let sc = agentic_scenario(fabric, cache_aware);
            let base = run_agentic_scenario(&sc);
            let mut wrapped = sc.clone();
            wrapped.cluster.fleet = Some(Fleet::single(sc.cluster.topology.clone()));
            let rep = run_agentic_scenario(&wrapped);
            assert_rows_identical(
                &format!("agentic/{fabric:?}/cache={cache_aware}"),
                &base.summary_kv(),
                &rep.summary_kv(),
            );
        }
    }
}

/// Run a co-scheduled preset bare and with a one-pool fleet installed
/// on *both* tenants (the trainer's lease pricing and the serving
/// cluster's migration pricing); serving rows, training rows, and the
/// broker ledger must all match.
fn assert_cosched_degenerate(label: &str, cfg: &CoschedConfig) {
    let base = run_cosched(cfg);
    let mut wrapped = cfg.clone();
    let single = Fleet::single(cfg.cluster.topology.clone());
    wrapped.train.fleet = Some(single.clone());
    wrapped.cluster.fleet = Some(single);
    let rep = run_cosched(&wrapped);
    assert_rows_identical(
        &format!("{label}/serving"),
        &base.serving.summary_kv(),
        &rep.serving.summary_kv(),
    );
    assert_rows_identical(
        &format!("{label}/train"),
        &base.train.summary_kv(),
        &rep.train.summary_kv(),
    );
    assert_eq!(base.broker.leases_granted, rep.broker.leases_granted, "{label}");
    assert_eq!(base.broker.leases_returned, rep.broker.leases_returned, "{label}");
    assert_eq!(base.broker.lease_misses, rep.broker.lease_misses, "{label}");
    assert_eq!(base.broker.free_at_end, rep.broker.free_at_end, "{label}");
    assert_eq!(base.broker.failed_at_end, rep.broker.failed_at_end, "{label}");
}

#[test]
fn uniform_fleet_is_bit_identical_on_cosched_presets() {
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        assert_cosched_degenerate(
            &format!("cosched/{fabric:?}"),
            &cosched_scenario(fabric, CoschedMode::Cosched),
        );
    }
    assert_cosched_degenerate(
        "cosched/static-partition",
        &cosched_scenario(ClusterFabric::Supernode, CoschedMode::StaticPartition),
    );
    assert_cosched_degenerate("cosched/seed42-faults", &fault_cosched_scenario());
    assert_cosched_degenerate("cosched/chaos-seed7", &chaos_cosched_scenario(7));
}

// ---- 2. partition conservation ----------------------------------------

#[test]
fn proportional_partition_conserves_total_under_random_caps() {
    let mut rng = Rng::new(42);
    for round in 0..64 {
        let n = 1 + rng.below(8) as usize;
        let weights: Vec<f64> = (0..n).map(|_| 0.05 + rng.next_f64()).collect();
        let total = rng.below(200) as usize;
        let mut caps: Vec<usize> = (0..n).map(|_| rng.below(64) as usize).collect();
        // keep the draw feasible: grow the first cap by any shortfall
        let shortfall = total.saturating_sub(caps.iter().sum::<usize>());
        caps[0] += shortfall;
        let sizes = proportional_partition(total, &weights, Some(caps.as_slice()));
        assert_eq!(
            sizes.iter().sum::<usize>(),
            total,
            "round {round}: items created or destroyed"
        );
        for (i, (&s, &c)) in sizes.iter().zip(&caps).enumerate() {
            assert!(s <= c, "round {round}: slot {i} over cap ({s} > {c})");
        }
    }
}

#[test]
fn fleet_partitions_fit_every_memory_spec() {
    let fleets = [
        ("mixed", Fleet::mixed_generations()),
        ("slow_rack", Fleet::slow_rack(FLEET_SLOW_RACK_DERATE)),
    ];
    // a 512 MB layer shard: caps bind at ~128 items per 64 GiB device
    let bytes_per_item = 512e6;
    for (label, fleet) in &fleets {
        let group = fleet.all_devices();
        let weights = compute_weights(fleet, &group);
        assert!(
            (weights.iter().sum::<f64>() - 1.0).abs() < 1e-9,
            "{label}: weights must normalize"
        );
        let caps = memory_caps(fleet, &group, bytes_per_item);
        for &total in &[64usize, 256, 1024] {
            let sizes = partition_for_group(fleet, &group, total, bytes_per_item);
            assert_eq!(
                sizes.iter().sum::<usize>(),
                total,
                "{label}/{total}: layer count not conserved"
            );
            for (i, (&s, &c)) in sizes.iter().zip(&caps).enumerate() {
                assert!(
                    s <= c,
                    "{label}/{total}: device {i} assigned {s} items over its HBM cap {c}"
                );
            }
        }
    }
    // roofline monotonicity: on the mixed fleet no 910B device ever
    // holds more than a 910C device; on the slow-rack fleet no derated
    // device holds more than a healthy one
    let mixed = &fleets[0].1;
    let sizes = partition_for_group(mixed, &mixed.all_devices(), 256, bytes_per_item);
    assert!(
        sizes[..32].iter().min() >= sizes[32..].iter().max(),
        "910C share must dominate 910B share: {sizes:?}"
    );
}

#[test]
fn uniform_fleet_partition_matches_count_split() {
    let fleet = Fleet::single(ClusterFabric::Supernode.topology());
    let group = fleet.all_devices();
    let weights = compute_weights(&fleet, &group);
    let total = 100usize;
    let sizes = proportional_partition(total, &weights, None);
    // uniform specs: total / n each, remainder to the lowest indices
    let n = group.len();
    for (i, &s) in sizes.iter().enumerate() {
        let expect = total / n + usize::from(i < total % n);
        assert_eq!(s, expect, "device {i}");
    }
}

// ---- 3. chaos x heterogeneity -----------------------------------------

/// One cell of the extended chaos grid: a heterogeneity-battery fleet
/// scenario shortened to the chaos horizon with a seeded
/// `random_fleet_plan` (link windows — inter-node face included —
/// training-device fails, serving crashes) layered on, retries armed.
fn fleet_chaos_scenario(which: FleetScenario, seed: u64) -> CoschedConfig {
    let mut cfg = fleet_cosched_scenario(which, true);
    cfg.horizon = CHAOS_HORIZON;
    cfg.train.train_until = CHAOS_HORIZON;
    let (plan, crashes) = random_fleet_plan(seed, CHAOS_HORIZON);
    cfg.cluster.faults = plan;
    cfg.cluster.failures = crashes;
    cfg.cluster.retry = Some(RetryPolicy::degraded_fabric());
    cfg
}

#[test]
fn chaos_grid_with_heterogeneous_pools_keeps_lease_ledger_a_partition() {
    let grid = [
        (
            FleetScenario::MixedGenerations,
            Fleet::mixed_generations().device_count(),
        ),
        (
            FleetScenario::SlowRack,
            Fleet::slow_rack(FLEET_SLOW_RACK_DERATE).device_count(),
        ),
    ];
    for (which, fleet_devices) in grid {
        for seed in 0..8u64 {
            let cfg = fleet_chaos_scenario(which, seed);
            let submitted = cfg.workload.generate(cfg.horizon).len();
            // run_cosched itself asserts pool drain and lease return;
            // the ledger partition below is the fleet-global extension
            let rep = run_cosched(&cfg);
            assert_tenant_isolation(&rep);
            assert_eq!(
                rep.serving.serving.outcomes.len() + rep.serving.serving.rejected as usize,
                submitted,
                "{which:?}/seed {seed}: requests lost"
            );
            assert!(
                rep.train.steps_lost <= rep.train.device_fails,
                "{which:?}/seed {seed}: more steps lost than fails"
            );
            assert_eq!(
                rep.broker.failed_at_end.len() as u64,
                rep.train.device_fails,
                "{which:?}/seed {seed}: failed-device ledger out of sync"
            );
            // every fleet device lands in exactly one terminal state:
            // broker-free, serving-held, crashed, or failed
            let mut seen = BTreeSet::new();
            for d in rep
                .broker
                .free_at_end
                .iter()
                .chain(&rep.serving.held_devices_at_end)
                .chain(&rep.serving.crashed_devices)
                .chain(&rep.broker.failed_at_end)
            {
                assert!(
                    seen.insert(d.0),
                    "{which:?}/seed {seed}: device {} in two ledgers",
                    d.0
                );
            }
            assert_eq!(
                seen.len(),
                fleet_devices,
                "{which:?}/seed {seed}: ledger does not cover the fleet"
            );
        }
    }
}
