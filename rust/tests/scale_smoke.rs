//! City-scale streaming smoke (ISSUE 8 tentpole acceptance): the
//! checked-in 1024-replica scenario must push ≥10^7 engine events and
//! ≥10^5 requests through the streaming sink with memory bounded by
//! the accumulators, not the event count.
//!
//! The full run is `#[ignore]`d so `cargo test` stays fast; the CI
//! `scale-smoke` job runs it in release mode under a hard wall-clock
//! timeout (`cargo test --release --test scale_smoke -- --include-ignored`).

use hyperparallel::serving::{city_scale_scenario, run_scenario};
use hyperparallel::sim::TraceMode;

#[test]
fn city_scale_preset_shape() {
    let sc = city_scale_scenario();
    assert!(sc.serving.fleet >= 1000, "city scale means 1000+ devices");
    assert_eq!(sc.serving.trace_mode, TraceMode::Streaming);
    assert!(sc.horizon >= 60.0);
}

#[test]
#[ignore = "release-mode CI scale-smoke job only: ~10^7 events"]
fn city_scale_run_streams_ten_million_events_bounded() {
    let sc = city_scale_scenario();
    let rep = run_scenario(&sc);

    assert!(
        rep.outcomes.len() >= 100_000,
        "city scale means >=1e5 requests, got {}",
        rep.outcomes.len()
    );
    assert!(
        rep.trace.interval_count() >= 10_000_000,
        "city scale means >=1e7 engine events, got {}",
        rep.trace.interval_count()
    );
    // the whole point: no interval log materialized, and the open-
    // interval buffer never grew with the event count
    assert!(rep.trace.indexed().is_none());
    assert!(
        rep.trace.peak_buffered() <= sc.serving.fleet,
        "peak buffered {} exceeds fleet {}",
        rep.trace.peak_buffered(),
        sc.serving.fleet
    );
    // sanity: the fleet actually worked the horizon
    assert!(rep.makespan >= sc.horizon);
    assert!(rep.completed() >= 90_000, "completed={}", rep.completed());
}
