//! Streaming-sink equivalence properties (ISSUE 8 satellite): the
//! incremental accumulators of [`TraceMode::Streaming`] must agree
//! *bitwise* with the CSR-indexed `SimResult` answers of
//! [`TraceMode::Indexed`] — over randomized interval sets, randomized
//! engine DAGs, and the checked-in PR 5–7 scenario presets at seed 42.
//!
//! The identity is by construction, not by tolerance: both modes fold
//! every interval into the same `StreamAccum` at the same execution
//! point (push → immediately, open → at close), so the floating-point
//! summation order is identical and the comparisons below use
//! `to_bits()`, never an epsilon.

use hyperparallel::hypermpmd::coschedule::{
    cosched_scenario, fault_cosched_scenario, run_cosched, CoschedMode,
};
use hyperparallel::serving::{
    agentic_scenario, crossover_scenario, run_agentic_scenario, run_cluster_scenario, run_scenario,
    smoke_scenario, ClusterFabric, ClusterMode,
};
use hyperparallel::sim::{tags, Engine, ResourceId, Trace, TraceCollector, TraceMode};
use hyperparallel::util::prop::{forall, usize_in, vec_of, Check};
use hyperparallel::util::rng::Rng;

/// One randomized sink operation: a final push or an open/truncate/
/// close pair (the cluster sim's two call shapes). Starts are derived
/// from a per-resource clock (`gap` seconds after the previous
/// interval on the same resource): the simulators serialize work per
/// resource, and that invariant is what makes the accumulator's fold
/// order coincide with the CSR index's start-sorted order — the
/// domain where the bitwise busy-time identity is guaranteed.
#[derive(Debug, Clone)]
struct Op {
    resource: usize,
    gap: f64,
    dur: f64,
    tag: u64,
    /// open + (optionally truncated) close instead of a plain push
    amend: bool,
    /// when amending: fraction of `dur` kept by the truncate
    keep: f64,
}

fn drive(mode: TraceMode, ops: &[Op], resources: usize) -> Trace {
    let mut tc = TraceCollector::new(mode);
    let mut clock = vec![0.0f64; resources];
    let mut makespan = 0.0f64;
    for op in ops {
        let start = clock[op.resource] + op.gap;
        let finish = start + op.dur;
        let end = if op.amend {
            let h = tc.open(ResourceId(op.resource), start, finish, op.tag);
            let kept = start + op.dur * op.keep;
            tc.truncate(h, kept, op.tag + 1);
            tc.close(h);
            kept
        } else {
            tc.push(ResourceId(op.resource), start, finish, op.tag);
            finish
        };
        clock[op.resource] = end;
        makespan = makespan.max(end);
    }
    tc.finish(makespan, resources)
}

#[test]
fn randomized_interval_sets_agree_bitwise_across_modes() {
    const RESOURCES: usize = 7;
    let gen_op = usize_in(0, u32::MAX as usize).map(|seed| {
        let mut r = Rng::new(seed as u64 ^ 0x9e37);
        Op {
            resource: r.range(0, RESOURCES),
            gap: r.uniform(0.0, 0.5),
            // zero-length markers (the DRAIN/CRASH shape) must stay
            // bitwise neutral for busy sums, so generate some
            dur: if r.below(5) == 0 {
                0.0
            } else {
                r.uniform(1e-6, 2.0)
            },
            tag: r.below(6),
            amend: r.below(3) == 0,
            keep: r.uniform(0.0, 1.0),
        }
    });
    forall(
        "stream accum == CSR index, bitwise",
        200,
        vec_of(gen_op, 0, 400),
        |ops| {
            let a = drive(TraceMode::Indexed, ops, RESOURCES);
            let b = drive(TraceMode::Streaming, ops, RESOURCES);
            if b.indexed().is_some() {
                return Check::Fail("streaming run kept an interval log".into());
            }
            if a.interval_count() != b.interval_count() {
                return Check::Fail(format!(
                    "count {} != {}",
                    a.interval_count(),
                    b.interval_count()
                ));
            }
            for r in 0..RESOURCES {
                let (x, y) = (a.busy_time(ResourceId(r)), b.busy_time(ResourceId(r)));
                if x.to_bits() != y.to_bits() {
                    return Check::Fail(format!("busy_time({r}): {x} != {y}"));
                }
            }
            if a.makespan().to_bits() != b.makespan().to_bits() {
                return Check::Fail(format!("makespan {} != {}", a.makespan(), b.makespan()));
            }
            let tags_a: Vec<u64> = a.accum().tag_values().collect();
            let tags_b: Vec<u64> = b.accum().tag_values().collect();
            if tags_a != tags_b {
                return Check::Fail(format!("tag sets differ: {tags_a:?} vs {tags_b:?}"));
            }
            for &t in &tags_a {
                if a.tagged_count(t) != b.tagged_count(t) {
                    return Check::Fail(format!("tagged_count({t}) differs"));
                }
                if a.tagged_busy(t).to_bits() != b.tagged_busy(t).to_bits() {
                    return Check::Fail(format!(
                        "tagged_busy({t}): {} != {}",
                        a.tagged_busy(t),
                        b.tagged_busy(t)
                    ));
                }
                for &p in &[0.0, 0.5, 0.99, 1.0] {
                    let (x, y) = (a.duration_pct(t, p), b.duration_pct(t, p));
                    if x.to_bits() != y.to_bits() {
                        return Check::Fail(format!("duration_pct({t},{p}): {x} != {y}"));
                    }
                }
            }
            Check::Pass
        },
    );
}

#[test]
fn randomized_engine_dags_agree_bitwise_across_modes() {
    forall(
        "engine run_trace(Indexed) == run_trace(Streaming)",
        40,
        usize_in(0, u32::MAX as usize),
        |&seed| {
            let mut r = Rng::new(seed as u64 ^ 0xda7a);
            let n_res = r.range(1, 9);
            let n_tasks = r.range(1, 300);
            let build = |rng_seed: u64| {
                let mut rng = Rng::new(rng_seed);
                let mut e = Engine::new();
                let rs: Vec<_> = (0..n_res).map(|i| e.add_resource(format!("r{i}"))).collect();
                let mut ids = Vec::with_capacity(n_tasks);
                for i in 0..n_tasks {
                    let mut deps = Vec::new();
                    if i > 0 {
                        for _ in 0..rng.range(0, 3.min(i)) {
                            deps.push(ids[rng.range(0, i)]);
                        }
                        deps.dedup();
                    }
                    let dur = rng.uniform(0.0, 1e-3);
                    ids.push(e.add_task(rs[i % n_res], dur, &deps, rng.below(4)));
                }
                e
            };
            let ta = build(seed as u64).run_trace(TraceMode::Indexed);
            let tb = build(seed as u64).run_trace(TraceMode::Streaming);
            if ta.makespan().to_bits() != tb.makespan().to_bits() {
                return Check::Fail("makespan differs".into());
            }
            for ri in 0..n_res {
                let (x, y) = (ta.busy_time(ResourceId(ri)), tb.busy_time(ResourceId(ri)));
                if x.to_bits() != y.to_bits() {
                    return Check::Fail(format!("busy_time({ri}): {x} != {y}"));
                }
            }
            for t in 0..4u64 {
                if ta.tagged_count(t) != tb.tagged_count(t) {
                    return Check::Fail("tagged_count differs".into());
                }
                if ta.tagged_busy(t).to_bits() != tb.tagged_busy(t).to_bits() {
                    return Check::Fail("tagged_busy differs".into());
                }
            }
            Check::Pass
        },
    );
}

/// Compare two summary_kv row sets bitwise (same keys, same order,
/// same bit patterns).
fn assert_kv_bitwise(label: &str, a: &[(String, f64)], b: &[(String, f64)]) {
    assert_eq!(a.len(), b.len(), "{label}: row count differs");
    for ((ka, va), (kb, vb)) in a.iter().zip(b) {
        assert_eq!(ka, kb, "{label}: key order diverged");
        assert_eq!(
            va.to_bits(),
            vb.to_bits(),
            "{label}: {ka} = {va} (indexed) vs {vb} (streaming)"
        );
    }
}

#[test]
fn smoke_scenario_reports_are_bit_identical_across_modes() {
    let mut sc = smoke_scenario(45.0, 0.2, 2);
    let a = run_scenario(&sc);
    sc.serving.trace_mode = TraceMode::Streaming;
    let b = run_scenario(&sc);
    assert_kv_bitwise("smoke_scenario", &a.summary_kv(), &b.summary_kv());
    assert_eq!(a.trace.interval_count(), b.trace.interval_count());
    assert!(b.trace.indexed().is_none());
    assert!(a.trace.indexed().is_some());
}

#[test]
fn cluster_crossover_reports_are_bit_identical_across_modes() {
    for mode in [ClusterMode::Colocated, ClusterMode::Disaggregated] {
        let mut sc = crossover_scenario(ClusterFabric::Supernode, mode);
        let a = run_cluster_scenario(&sc);
        sc.cluster.trace_mode = TraceMode::Streaming;
        let b = run_cluster_scenario(&sc);
        assert_kv_bitwise(
            &format!("crossover/{mode:?}"),
            &a.summary_kv(),
            &b.summary_kv(),
        );
        assert_eq!(
            a.serving.trace.interval_count(),
            b.serving.trace.interval_count()
        );
        // streaming buffers only the concurrently-open intervals —
        // bounded by the instance count, not the interval count
        assert!(b.serving.trace.peak_buffered() <= sc.cluster.instances.len() + 1);
    }
}

#[test]
fn cosched_reports_are_bit_identical_across_modes() {
    let mut cfg = cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched);
    cfg.horizon = 4.0;
    cfg.train.train_until = 4.0;
    let a = run_cosched(&cfg);
    cfg.cluster.trace_mode = TraceMode::Streaming;
    let b = run_cosched(&cfg);
    assert_kv_bitwise(
        "cosched/serving",
        &a.serving.summary_kv(),
        &b.serving.summary_kv(),
    );
    assert_kv_bitwise("cosched/train", &a.train.summary_kv(), &b.train.summary_kv());
    assert_eq!(
        a.train.trace.makespan().to_bits(),
        b.train.trace.makespan().to_bits()
    );
    assert!(b.train.trace.indexed().is_none());
}

#[test]
fn fault_cosched_reports_are_bit_identical_across_modes() {
    let mut cfg = fault_cosched_scenario();
    let a = run_cosched(&cfg);
    cfg.cluster.trace_mode = TraceMode::Streaming;
    let b = run_cosched(&cfg);
    assert_kv_bitwise(
        "faults/serving",
        &a.serving.summary_kv(),
        &b.serving.summary_kv(),
    );
    assert_kv_bitwise("faults/train", &a.train.summary_kv(), &b.train.summary_kv());
    // the crash/truncate path folds the truncated span in both modes
    assert_eq!(
        a.serving.trace.tagged_count(tags::CRASH),
        b.serving.trace.tagged_count(tags::CRASH)
    );
    assert_eq!(
        a.train.trace.tagged_busy(tags::DEVICE_FAIL).to_bits(),
        b.train.trace.tagged_busy(tags::DEVICE_FAIL).to_bits()
    );
}

#[test]
fn agentic_reports_are_bit_identical_across_modes() {
    let mut sc = agentic_scenario(ClusterFabric::Supernode, true);
    let a = run_agentic_scenario(&sc);
    sc.cluster.trace_mode = TraceMode::Streaming;
    let b = run_agentic_scenario(&sc);
    assert_kv_bitwise("agentic", &a.summary_kv(), &b.summary_kv());
}
