//! Integration: the framework layers composed end to end on the
//! simulated substrate — planner → offload orchestration → simulator,
//! process groups → MPMD schedulers, and cross-module property tests.

use hyperparallel::config::ModelDesc;
use hyperparallel::coordinator::Coordinator;
use hyperparallel::graph::{lower_to_sim, GraphBuilder};
use hyperparallel::hypermpmd::{
    omni_modal_example, schedule_dynamic, schedule_gang, schedule_single_controller,
    schedule_static, OmniModalWorkload, ProcessGroupMap, RlWorkload,
};
use hyperparallel::hyperoffload::{orchestrate, OrchestratorConfig};
use hyperparallel::hypershard::{best_plan, plan, PlannerConfig};
use hyperparallel::memory::TransferEngine;
use hyperparallel::supernode::Topology;
use hyperparallel::trainer::scenarios::OffloadTrainingScenario;
use hyperparallel::util::prop::{forall, pair_of, usize_in, Check};

#[test]
fn coordinator_plans_then_offload_executes() {
    // Step 1+2: plan
    let coord = Coordinator::new(Topology::tiny()).with_offload(true);
    let summary = coord.plan_model(&ModelDesc::llama_8b());
    assert!(summary.requires_offload);
    // Step 3: orchestrate the step graph under HyperOffload and run it
    let scenario = OffloadTrainingScenario::llama8b();
    let (g, sizes) = scenario.build_graph();
    let plan = orchestrate(&g, &sizes, &OrchestratorConfig::default());
    let mut low = lower_to_sim(
        &plan.graph,
        &scenario.topo,
        &TransferEngine::supernode(),
        scenario.cube_efficiency,
    );
    let res = low.run();
    assert!(res.makespan > 0.0);
    hyperparallel::hyperoffload::orchestrator::verify_residency(
        &plan,
        &low.engine,
        &low.task_of_node,
    )
    .unwrap();
}

#[test]
fn process_groups_feed_mpmd_schedulers() {
    let topo = Topology::matrix384();
    let map = ProcessGroupMap::from_json(omni_modal_example(), topo.device_count()).unwrap();
    // one scheduling group per mapped module (minus the control group)
    let groups = map.groups.iter().filter(|g| g.module != "control").count();
    let w = OmniModalWorkload::paper_shape(8);
    assert_eq!(groups, w.modules.len());
    let stat = schedule_static(&w);
    let dyn_ = schedule_dynamic(&w, groups);
    assert!(dyn_.makespan <= stat.makespan);
}

#[test]
fn planner_offload_interaction() {
    // without offload, llama-8b on one 8-die board needs tp*pp >= 4;
    // with HyperOffload, dp-heavy plans become admissible.
    let topo = Topology::tiny();
    let model = ModelDesc::llama_8b();
    let strict = PlannerConfig {
        allow_offload: false,
        ..Default::default()
    };
    let relaxed = PlannerConfig {
        allow_offload: true,
        ..Default::default()
    };
    let n_strict = plan(&model, &topo, &strict).len();
    let n_relaxed = plan(&model, &topo, &relaxed).len();
    assert!(n_relaxed > n_strict);
    let best = best_plan(&model, &topo, &relaxed).unwrap();
    assert!(best.step_time > 0.0);
}

#[test]
fn rl_single_controller_never_loses_to_gang() {
    forall(
        "sc-beats-gang",
        40,
        pair_of(usize_in(2, 6), usize_in(8, 48)),
        |&(models, rollouts)| {
            let w = RlWorkload {
                models,
                rollouts_per_model: rollouts,
                rollout_sigma: 0.7,
                rollout_mean: 1.0,
                eval_frac: 0.1,
                update_duration: 4.0,
            };
            let tasks = w.generate((models * rollouts) as u64);
            let devices = models * 8;
            let gang = schedule_gang(&tasks, devices).expect("one device per model");
            let sc = schedule_single_controller(&tasks, devices, 8).expect("one device per model");
            Check::from_bool(
                sc.makespan <= gang.makespan * 1.001,
                &format!("sc {} > gang {}", sc.makespan, gang.makespan),
            )
        },
    );
}

#[test]
fn offload_gain_holds_across_models() {
    for model in [ModelDesc::llama_8b(), ModelDesc::dense_30b()] {
        let s = OffloadTrainingScenario {
            model,
            topo: Topology::tiny(),
            cube_efficiency: 0.42,
        };
        let base = s.baseline_step();
        let hyper = s.hyperoffload_step(2);
        assert!(
            hyper < base,
            "{}: hyper {hyper} >= base {base}",
            s.model.name
        );
    }
}

#[test]
fn prop_dynamic_schedule_dominates_static() {
    forall(
        "dynamic-dominates",
        30,
        pair_of(usize_in(2, 24), usize_in(2, 6)),
        |&(microbatches, modules)| {
            let w = OmniModalWorkload {
                modules: (0..modules)
                    .map(|i| hyperparallel::hypermpmd::SubModule {
                        name: format!("m{i}"),
                        time_per_microbatch: 10e-3 * (1 + i % 3) as f64,
                        inputs: if i == 0 { vec![] } else { vec![i - 1] },
                    })
                    .collect(),
                microbatches,
            };
            let stat = schedule_static(&w);
            let dyn_ = schedule_dynamic(&w, modules);
            Check::from_bool(
                dyn_.makespan <= stat.makespan * 1.001,
                &format!("dyn {} > stat {}", dyn_.makespan, stat.makespan),
            )
        },
    );
}

#[test]
fn prop_orchestrated_graph_preserves_compute() {
    // the offload pass must not drop or duplicate compute nodes
    forall("pass-preserves-compute", 50, usize_in(1, 40), |&layers| {
        let mut b = GraphBuilder::new();
        let d = hyperparallel::supernode::DeviceId(0);
        let mut sizes = hyperparallel::hyperoffload::orchestrator::RegionSizes::new();
        for i in 0..layers {
            let r = hyperparallel::memory::RegionId(i);
            sizes.insert(r, 1024);
            b.compute_reading(d, format!("l{i}"), 1e9, 0.0, vec![r], &[]);
        }
        let g = b.finish();
        let plan = orchestrate(&g, &sizes, &OrchestratorConfig::default());
        let compute_in = g.count(|n| matches!(n.op, hyperparallel::graph::OpKind::Compute { .. }));
        let compute_out = plan
            .graph
            .count(|n| matches!(n.op, hyperparallel::graph::OpKind::Compute { .. }));
        Check::from_bool(
            compute_in == compute_out && plan.graph.check().is_ok(),
            "compute nodes changed or graph invalid",
        )
    });
}
