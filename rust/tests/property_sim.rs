//! Property tests for the discrete-event engine and the indexed
//! `SimResult` (ISSUE 1 satellite): per-resource intervals never
//! overlap, the makespan equals the max finish time, and every indexed
//! metric agrees **bit-identically** with a naive reference
//! implementation that re-scans the raw interval trace.

use hyperparallel::sim::{Engine, Interval, ResourceId, SimResult, TaskId};
use hyperparallel::util::prop::{f64_in, forall, pair_of, usize_in, vec_of, Check};

/// A generated workload: resource count + per-task
/// (raw selector, (duration, dependency count)).
type Spec = (usize, Vec<(usize, (f64, usize))>);

fn spec_gen() -> hyperparallel::util::prop::Gen<Spec> {
    pair_of(
        usize_in(1, 5),
        vec_of(
            pair_of(usize_in(0, 97), pair_of(f64_in(0.0, 2.0), usize_in(0, 3))),
            0,
            120,
        ),
    )
}

/// Deterministically materialize a workload spec into an engine.
fn build(spec: &Spec) -> Engine {
    let (nres, tasks) = spec;
    let mut e = Engine::new();
    let rs: Vec<_> = (0..*nres).map(|i| e.add_resource(format!("r{i}"))).collect();
    let mut ids: Vec<TaskId> = Vec::with_capacity(tasks.len());
    let mut deps: Vec<TaskId> = Vec::new();
    for (j, (raw, (dur, ndeps))) in tasks.iter().enumerate() {
        deps.clear();
        if j > 0 {
            for k in 0..*ndeps {
                deps.push(ids[(raw + 7 * k + j) % j]);
            }
            deps.sort();
            deps.dedup();
        }
        let t = e.add_task(rs[raw % nres], *dur, &deps, (raw % 5) as u64);
        if raw % 4 == 0 {
            e.set_release(t, (raw % 11) as f64 * 0.1);
        }
        ids.push(t);
    }
    e
}

// ---- naive reference implementations (full scans over the trace) ----

fn naive_busy(res: &SimResult, r: ResourceId) -> f64 {
    res.intervals
        .iter()
        .filter(|iv| iv.resource == r)
        .map(|iv| iv.finish - iv.start)
        .sum()
}

fn naive_overlap(res: &SimResult, a: ResourceId, b: ResourceId) -> f64 {
    let ia: Vec<&Interval> = res.intervals.iter().filter(|iv| iv.resource == a).collect();
    let ib: Vec<&Interval> = res.intervals.iter().filter(|iv| iv.resource == b).collect();
    let mut overlap = 0.0;
    for x in &ia {
        for y in &ib {
            let lo = x.start.max(y.start);
            let hi = x.finish.min(y.finish);
            if hi > lo {
                overlap += hi - lo;
            }
        }
    }
    overlap
}

fn naive_tagged(res: &SimResult, tag: u64) -> Vec<TaskId> {
    res.intervals
        .iter()
        .filter(|iv| iv.tag == tag)
        .map(|iv| iv.task)
        .collect()
}

// ---- properties -----------------------------------------------------

#[test]
fn per_resource_intervals_never_overlap_and_are_sorted() {
    forall("sim-no-overlap", 120, spec_gen(), |spec| {
        let res = build(spec).run();
        for r in 0..spec.0 {
            let bucket = res.per_resource(ResourceId(r));
            for w in bucket.windows(2) {
                if w[0].start > w[1].start {
                    return Check::Fail(format!("bucket {r} not start-sorted"));
                }
                if w[0].finish > w[1].start {
                    return Check::Fail(format!(
                        "overlap on resource {r}: [{}, {}) then [{}, {})",
                        w[0].start, w[0].finish, w[1].start, w[1].finish
                    ));
                }
            }
        }
        Check::Pass
    });
}

#[test]
fn makespan_equals_max_finish() {
    forall("sim-makespan", 120, spec_gen(), |spec| {
        let res = build(spec).run();
        let max_finish = res
            .intervals
            .iter()
            .map(|iv| iv.finish)
            .fold(0.0f64, f64::max);
        Check::from_bool(
            res.makespan.to_bits() == max_finish.to_bits(),
            &format!("makespan {} != max finish {}", res.makespan, max_finish),
        )
    });
}

#[test]
fn indexed_metrics_bit_identical_to_naive_scans() {
    forall("sim-indexed-vs-naive", 100, spec_gen(), |spec| {
        let res = build(spec).run();
        for r in 0..spec.0 {
            let rid = ResourceId(r);
            let (fast, slow) = (res.busy_time(rid), naive_busy(&res, rid));
            if fast.to_bits() != slow.to_bits() {
                return Check::Fail(format!("busy_time({r}): {fast} != naive {slow}"));
            }
            for r2 in 0..spec.0 {
                let rid2 = ResourceId(r2);
                let (fast, slow) = (res.overlap_time(rid, rid2), naive_overlap(&res, rid, rid2));
                if fast.to_bits() != slow.to_bits() {
                    return Check::Fail(format!(
                        "overlap_time({r},{r2}): {fast} != naive {slow}"
                    ));
                }
            }
        }
        for tag in 0..5u64 {
            let via_index: Vec<TaskId> = res.intervals_tagged(tag).map(|iv| iv.task).collect();
            if via_index != naive_tagged(&res, tag) {
                return Check::Fail(format!("tag index mismatch for tag {tag}"));
            }
            if res.tagged_count(tag) != via_index.len() {
                return Check::Fail(format!("tagged_count mismatch for tag {tag}"));
            }
        }
        Check::Pass
    });
}

#[test]
fn windowed_busy_is_consistent_with_totals() {
    forall("sim-busy-window", 100, spec_gen(), |spec| {
        let res = build(spec).run();
        for r in 0..spec.0 {
            let rid = ResourceId(r);
            let whole = res.busy_in_window(rid, 0.0, res.makespan + 1.0);
            if (whole - res.busy_time(rid)).abs() > 1e-9 {
                return Check::Fail(format!(
                    "full window {} != busy_time {}",
                    whole,
                    res.busy_time(rid)
                ));
            }
            // split at an arbitrary interior point: halves must sum back
            let mid = res.makespan * 0.37;
            let sum = res.busy_in_window(rid, 0.0, mid) + res.busy_in_window(rid, mid, res.makespan + 1.0);
            if (sum - res.busy_time(rid)).abs() > 1e-9 {
                return Check::Fail(format!("window split {sum} != {}", res.busy_time(rid)));
            }
        }
        Check::Pass
    });
}

#[test]
fn utilization_bounded_and_conserved() {
    forall("sim-utilization", 100, spec_gen(), |spec| {
        let res = build(spec).run();
        let mut total_busy = 0.0;
        for r in 0..spec.0 {
            let u = res.utilization(ResourceId(r));
            if !(0.0..=1.0 + 1e-12).contains(&u) {
                return Check::Fail(format!("utilization({r}) = {u} out of [0,1]"));
            }
            total_busy += res.busy_time(ResourceId(r));
        }
        Check::from_bool(
            total_busy <= spec.0 as f64 * res.makespan + 1e-9,
            &format!(
                "busy {} exceeds resources x makespan {}",
                total_busy,
                spec.0 as f64 * res.makespan
            ),
        )
    });
}

#[test]
fn reruns_are_bit_identical() {
    forall("sim-determinism", 60, spec_gen(), |spec| {
        let a = build(spec).run();
        let b = build(spec).run();
        if a.makespan.to_bits() != b.makespan.to_bits() {
            return Check::Fail("makespan differs across reruns".into());
        }
        if a.intervals.len() != b.intervals.len() {
            return Check::Fail("interval count differs".into());
        }
        for (x, y) in a.intervals.iter().zip(&b.intervals) {
            let same = x.task == y.task
                && x.resource == y.resource
                && x.start.to_bits() == y.start.to_bits()
                && x.finish.to_bits() == y.finish.to_bits()
                && x.tag == y.tag;
            if !same {
                return Check::Fail(format!("interval differs: {x:?} vs {y:?}"));
            }
        }
        Check::Pass
    });
}
