//! Fleet scenario tests (ISSUE 9 acceptance): the three checked-in
//! seed-42 hyper-heterogeneous fleet scenarios, each gated
//! heterogeneity-aware vs naive-uniform.
//!
//! Gates are calibrated by the Python mirrors
//! (`tools/cosched_simcheck.py`, `tools/cluster_simcheck.py`):
//!
//! 1. mixed generations (910C pool + 910B pool): aware/naive
//!    steps-by-deadline = 113 vs 85 ≈ 1.33× (gate ≥ 1.15), with the
//!    aware trainer's inter-node reshard bill at or below the blind
//!    harvester's (mirror: 0.66 s vs 1.06 s);
//! 2. slow rack (one supernode, rack 0 derated 2×): 70 vs 42 ≈ 1.67×
//!    (gate ≥ 1.25) — single pool, so the whole gap is
//!    compute-proportional partitioning vs uniform-plan-replay;
//! 3. cross-supernode disaggregated prefill: pipeline-per-supernode
//!    placement cuts KV transfer seconds ≈ 3.9× vs the naive
//!    prefill-pool/decode-pool split whose every handoff crosses the
//!    DCN (gate ≥ 2×).
//!
//! Serving rides along in every cell: the p99 TTFT SLO holds and no
//! request is shed, heterogeneous fleet or not.

use hyperparallel::hypermpmd::coschedule::{
    assert_tenant_isolation, cosched_slo, fleet_cosched_scenario, run_cosched, CoschedReport,
    FleetScenario,
};
use hyperparallel::serving::{
    cluster_slo, fleet_prefill_scenario, run_cluster_scenario, AUTOSCALE_MEAN_RATE, CLUSTER_RATES,
};

/// Run one (scenario, aware) cell and assert the invariants every cell
/// must satisfy: tenant isolation, no shed serving load, steps done.
fn fleet_cell(which: FleetScenario, aware: bool) -> CoschedReport {
    let rep = run_cosched(&fleet_cosched_scenario(which, aware));
    assert_tenant_isolation(&rep);
    let op = rep.serving.operating_point(AUTOSCALE_MEAN_RATE, &cosched_slo());
    assert_eq!(op.rejected, 0, "{which:?}/aware={aware}: serving shed load");
    assert!(
        op.attains_slo,
        "{which:?}/aware={aware}: serving must hold the SLO, p99 ttft {}",
        op.p99_ttft
    );
    assert!(rep.train.steps_by_deadline > 0, "{which:?}/aware={aware}");
    rep
}

#[test]
fn mixed_generations_aware_beats_naive_uniform() {
    let aware = fleet_cell(FleetScenario::MixedGenerations, true);
    let naive = fleet_cell(FleetScenario::MixedGenerations, false);
    let gain = aware.train.steps_by_deadline as f64 / naive.train.steps_by_deadline as f64;
    assert!(
        gain >= 1.15,
        "compute-proportional assignment must out-train the naive-uniform \
         plan on mixed generations: {gain:.3} ({} vs {})",
        aware.train.steps_by_deadline,
        naive.train.steps_by_deadline
    );
    // the aware trainer crosses the DCN only when the reshard pays for
    // itself, so its reshard bill stays at or below the blind
    // harvester's (mirror: 0.66 s vs 1.06 s)
    assert!(
        aware.train.reshard_seconds <= naive.train.reshard_seconds * 1.05,
        "aware reshard bill {} must not exceed the blind harvester's {}",
        aware.train.reshard_seconds,
        naive.train.reshard_seconds
    );
    // the harvest spans both supernodes: crossing did happen where it
    // paid (the whole second pool is idle capacity)
    assert!(
        aware.train.peak_devices > 32,
        "the aware trainer must harvest beyond its home supernode: peak {}",
        aware.train.peak_devices
    );
}

#[test]
fn slow_rack_aware_beats_naive_uniform() {
    let aware = fleet_cell(FleetScenario::SlowRack, true);
    let naive = fleet_cell(FleetScenario::SlowRack, false);
    let gain = aware.train.steps_by_deadline as f64 / naive.train.steps_by_deadline as f64;
    assert!(
        gain >= 1.25,
        "compute-proportional assignment must out-train uniform-plan \
         replay on the throttled rack: {gain:.3} ({} vs {})",
        aware.train.steps_by_deadline,
        naive.train.steps_by_deadline
    );
    // single pool: the gap is pure scheduling, not crossing policy, so
    // both cells pay comparable reshard bills on the same fabric
    assert!(aware.train.reshards > 0 && naive.train.reshards > 0);
}

#[test]
fn fleet_scenarios_are_deterministic() {
    let a = run_cosched(&fleet_cosched_scenario(FleetScenario::MixedGenerations, true));
    let b = run_cosched(&fleet_cosched_scenario(FleetScenario::MixedGenerations, true));
    assert_eq!(a.train.steps_by_deadline, b.train.steps_by_deadline);
    assert_eq!(
        a.train.reshard_seconds.to_bits(),
        b.train.reshard_seconds.to_bits()
    );
    assert_eq!(a.serving.summary_kv(), b.serving.summary_kv());
}

#[test]
fn cross_supernode_prefill_aware_placement_wins() {
    let aware = run_cluster_scenario(&fleet_prefill_scenario(true));
    let naive = run_cluster_scenario(&fleet_prefill_scenario(false));
    // both cells serve the full workload (mirror: 175/175 requests)
    assert!(aware.completed() > 0 && naive.completed() > 0);
    assert_eq!(aware.serving.rejected, 0, "aware cell shed load");
    assert_eq!(naive.serving.rejected, 0, "naive cell shed load");
    assert!(aware.kv_migrations > 0 && naive.kv_migrations > 0);
    // the headline: per-supernode pipelines keep KV handoffs on the
    // in-pool fabric; the naive split pays the DCN on every one
    // (mirror: 0.92 s vs 0.23 s ≈ 3.9×)
    assert!(
        naive.kv_xfer_time >= 2.0 * aware.kv_xfer_time,
        "cross-supernode handoffs must dominate KV transfer seconds: \
         naive {} vs aware {}",
        naive.kv_xfer_time,
        aware.kv_xfer_time
    );
    // serving quality holds at the scenario's doubled base rate
    let rate = 2.0 * CLUSTER_RATES[0];
    let op = aware.operating_point(rate, &cluster_slo());
    assert!(
        op.attains_slo,
        "aware fleet cell must hold the serving SLO: p99 ttft {}",
        op.p99_ttft
    );
}
