//! Fleet-wide fault injection + recovery (ISSUE 6 acceptance).
//!
//! The checked-in seed-42 scenario layers the fault plan of
//! `faults::chaos::fault_scenario_plan` — one training `DeviceFail` at
//! t=18 s plus a 10× rack-tier degrade over `[20, 26)` s — onto the
//! PR 5 co-scheduled run (32-device pool, diurnal serving + harvesting
//! trainer). Calibrated against `tools/cosched_simcheck.py`: zero
//! serving requests lost, the trainer loses at most one step to the
//! fail (mirror: exactly 1, MTTR ≈ 41 ms), and p99 TTFT stays within
//! 2× of the fault-free run (mirror: 1.00×, 0.3700 s vs 0.3698 s).
//!
//! The chaos property suite then runs ≥16 seeded random schedules
//! (`faults::chaos::random_plan`: 1–3 link windows, 0–2 device fails,
//! 0–1 serving crashes — same Rng draw order as the mirror) through
//! the same setup and asserts the global invariants under every one:
//! request conservation, lease-ledger partition, page custody, and
//! tenant overlap-freedom.

use hyperparallel::faults::chaos::CHAOS_SEEDS;
use hyperparallel::faults::{FaultPlan, LinkDegrade, RetryPolicy};
use hyperparallel::hypermpmd::coschedule::{
    assert_tenant_isolation, chaos_cosched_scenario, cosched_scenario, cosched_slo,
    fault_cosched_scenario, run_cosched, CoschedMode, COSCHED_POOL_DEVICES,
};
use hyperparallel::hyperoffload::kvcache::KvCacheConfig;
use hyperparallel::serving::{
    simulate_cluster, ArrivalProcess, ClusterConfig, ClusterFabric, CostModel, InstanceCrash,
    InstanceRole, InstanceSpec, LengthDist, WorkloadConfig, AUTOSCALE_MEAN_RATE,
};
use hyperparallel::serving::{spread_placement, ClusterReport};
use hyperparallel::sim::tags;
use hyperparallel::supernode::{LinkTier, Topology};

// ---- the checked-in seed-42 acceptance scenario ------------------------

#[test]
fn seed42_faults_lose_no_requests_and_at_most_one_step() {
    let base = run_cosched(&cosched_scenario(
        ClusterFabric::Supernode,
        CoschedMode::Cosched,
    ));
    let cfg = fault_cosched_scenario();
    let submitted = cfg.workload.generate(cfg.horizon).len();
    let rep = run_cosched(&cfg);

    // serving resilience: every request completed, none shed
    let slo = cosched_slo();
    let op = rep.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert_eq!(
        op.completed + op.rejected as usize,
        submitted,
        "requests lost under faults"
    );
    assert_eq!(op.rejected, 0, "faults must not shed serving load");

    // p99 TTFT within 2x of the fault-free run (mirror: 1.00x)
    let base_op = base.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert!(
        op.p99_ttft <= 2.0 * base_op.p99_ttft,
        "faulted p99 TTFT {} vs fault-free {}",
        op.p99_ttft,
        base_op.p99_ttft
    );

    // training recovery: the fail cost at most one step, paid one
    // checkpoint-restore, and recovered in well under a second
    assert_eq!(rep.train.device_fails, 1);
    assert_eq!(rep.broker.failed_at_end.len(), 1);
    assert!(
        rep.train.steps_lost <= 1,
        "checkpoint-restore loses at most a step: {}",
        rep.train.steps_lost
    );
    assert!(rep.train.restores >= 1, "the fail must force a restore");
    assert!(rep.train.restore_seconds > 0.0, "a restore is never free");
    assert!(
        rep.train.mttr_seconds > 0.0 && rep.train.mttr_seconds < 1.0,
        "MTTR out of range: {}",
        rep.train.mttr_seconds
    );
    assert!(
        rep.train.steps_by_deadline >= base.train.steps_by_deadline.saturating_sub(5),
        "the fault must cost a few steps at most: {} vs fault-free {}",
        rep.train.steps_by_deadline,
        base.train.steps_by_deadline
    );

    // the degrade window steered at least one migration away from the
    // slow path (mirror: hedged = 1), and the events are in the traces
    assert!(rep.serving.hedged >= 1, "no migration hedged");
    assert!(rep.train.trace.tagged_count(tags::DEVICE_FAIL) > 0);
    assert!(rep.train.trace.tagged_count(tags::RESTORE) > 0);
    assert_tenant_isolation(&rep);

    // lease conservation with the failed device as a terminal state
    let accounted = rep.broker.free_at_end.len()
        + rep.serving.held_devices_at_end.len()
        + rep.serving.crashed_devices.len()
        + rep.broker.failed_at_end.len();
    assert_eq!(accounted, COSCHED_POOL_DEVICES);
}

// ---- the chaos property suite ------------------------------------------

#[test]
fn chaos_schedules_preserve_global_invariants() {
    assert!(CHAOS_SEEDS >= 16, "acceptance demands >=16 schedules");
    for seed in 0..CHAOS_SEEDS {
        let cfg = chaos_cosched_scenario(seed);
        let submitted = cfg.workload.generate(cfg.horizon).len();
        // run_cosched itself asserts the lease set-partition, page
        // custody (pool drain), and trainer lease return
        let rep = run_cosched(&cfg);
        assert_tenant_isolation(&rep);
        assert_eq!(
            rep.serving.serving.outcomes.len() + rep.serving.serving.rejected as usize,
            submitted,
            "seed {seed}: requests lost"
        );
        assert!(
            rep.train.steps_lost <= rep.train.device_fails,
            "seed {seed}: more steps lost than fails"
        );
        assert_eq!(
            rep.broker.failed_at_end.len() as u64,
            rep.train.device_fails,
            "seed {seed}: failed-device ledger out of sync"
        );
        assert_eq!(
            rep.serving.crashed_devices.len() as u64,
            rep.serving.crashes,
            "seed {seed}: crashed-device ledger out of sync"
        );
        let accounted = rep.broker.free_at_end.len()
            + rep.serving.held_devices_at_end.len()
            + rep.serving.crashed_devices.len()
            + rep.broker.failed_at_end.len();
        assert_eq!(accounted, COSCHED_POOL_DEVICES, "seed {seed}");
    }
}

// ---- cluster-level custody regressions ---------------------------------

fn fault_device() -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 1024,
        tokens_per_page: 16,
        weight_bytes: 1 << 20,
        hbm_usable: (1 << 20) + 64 * 16 * 1024,
        hbm_bw: 1.6e12,
        pool_bw: 392e9,
        attn_tokens_per_s: 40e6,
    }
}

fn custody_cluster(
    n_decode: usize,
    failures: Vec<InstanceCrash>,
    faults: FaultPlan,
    retry: Option<RetryPolicy>,
) -> ClusterConfig {
    let topology = Topology::matrix384();
    let places = spread_placement(&topology, 2 + n_decode);
    let mut instances = vec![
        InstanceSpec {
            device: places[0],
            role: InstanceRole::Prefill,
            slots: 2,
        },
        InstanceSpec {
            device: places[1],
            role: InstanceRole::Prefill,
            slots: 2,
        },
    ];
    for i in 0..n_decode {
        instances.push(InstanceSpec {
            device: places[2 + i],
            role: InstanceRole::Decode,
            slots: 4,
        });
    }
    let mut b = ClusterConfig::builder(topology, instances, CostModel::new(fault_device(), 0.0))
        .max_seq(512)
        .failures(failures)
        .faults(faults);
    if let Some(r) = retry {
        b = b.retry(r);
    }
    b.build()
}

fn custody_workload(seed: u64) -> Vec<hyperparallel::serving::Request> {
    WorkloadConfig {
        arrival: ArrivalProcess::Poisson { rate: 200.0 },
        prompt: LengthDist::Uniform { lo: 24, hi: 72 },
        output: LengthDist::Uniform { lo: 6, hi: 18 },
        seed,
    }
    .generate(0.3)
}

fn assert_request_conservation(rep: &ClusterReport, submitted: usize, label: &str) {
    assert_eq!(
        rep.serving.outcomes.len() + rep.serving.rejected as usize,
        submitted,
        "{label}: requests lost or duplicated"
    );
}

/// Regression (ISSUE 6 satellite): an instance crash while KV pages
/// are parked for migration must release custody at *both* ends. With
/// the sole decode instance dead, every prefill→decode migration hits
/// the reject path with pages still parked at its source — before the
/// fix the source pool kept them forever and the drain-time page
/// conservation assert (inside `into_report`) fired.
#[test]
fn crash_with_kv_in_custody_releases_both_ends() {
    let reqs = custody_workload(5);
    let cfg = custody_cluster(
        1,
        vec![InstanceCrash {
            time: 0.05,
            instance: 2,
        }],
        FaultPlan::empty(),
        None,
    );
    // into_report (called by simulate_cluster) asserts every live pool
    // drained — the custody invariant this test exists to guard
    let rep = simulate_cluster(&cfg, &reqs);
    assert_eq!(rep.crashes, 1);
    assert!(
        rep.serving.rejected > 0,
        "migrations after the decode death must reject, not hang"
    );
    assert_request_conservation(&rep, reqs.len(), "decode-crash custody");
}

/// Regression (ISSUE 6 satellite): a crash of the *source* instance
/// while migrations are parked in the retry queue must clear their
/// page custody — the retried entry re-routes as a fresh request
/// instead of pulling pages from a dead pool.
#[test]
fn source_crash_while_retries_parked_clears_custody() {
    let reqs = custody_workload(9);
    let mut faults = FaultPlan::empty();
    for tier in [LinkTier::Board, LinkTier::Rack, LinkTier::CrossRack] {
        faults.link_windows.push(LinkDegrade {
            tier,
            start: 0.0,
            end: 1.0,
            bandwidth_scale: 1e-3,
            latency_scale: 10.0,
        });
    }
    // timeout far below any degraded transfer: every migration parks
    // (twice) before accepting the slow path; hedge disabled so the
    // park path, not the hedge path, is what's exercised
    let retry = RetryPolicy {
        timeout: 1e-5,
        backoff: 1e-5,
        max_attempts: 2,
        hedge: 0.0,
    };
    let cfg = custody_cluster(
        2,
        vec![InstanceCrash {
            time: 0.04,
            instance: 0,
        }],
        faults,
        Some(retry),
    );
    let rep = simulate_cluster(&cfg, &reqs);
    assert_eq!(rep.crashes, 1);
    assert!(
        rep.retries_scheduled > 0,
        "the degraded window must park migrations"
    );
    assert!(
        rep.serving.trace.tagged_count(tags::RETRY) as u64 == rep.retries_scheduled,
        "every park leaves a retry marker"
    );
    assert!(
        rep.serving.trace.tagged_count(tags::LINK_DEGRADE) > 0,
        "exhausted retries must flag the slow transfer they accept"
    );
    assert_request_conservation(&rep, reqs.len(), "source-crash retry custody");
}

/// A fault plan whose windows never cover the run leaves every report
/// field bit-identical to the fault-free run — the no-fault fast path
/// is provably unperturbed at the cluster level too.
#[test]
fn dormant_fault_plan_is_bit_identical_to_fault_free() {
    let reqs = custody_workload(3);
    let clean = custody_cluster(2, vec![], FaultPlan::empty(), None);
    let mut dormant_plan = FaultPlan::empty();
    dormant_plan.link_windows.push(LinkDegrade {
        tier: LinkTier::Rack,
        start: 50.0,
        end: 60.0,
        bandwidth_scale: 0.01,
        latency_scale: 10.0,
    });
    let dormant = custody_cluster(2, vec![], dormant_plan, Some(RetryPolicy::degraded_fabric()));
    let a = simulate_cluster(&clean, &reqs);
    let b = simulate_cluster(&dormant, &reqs);
    assert_eq!(a.serving.makespan.to_bits(), b.serving.makespan.to_bits());
    assert_eq!(a.serving.outcomes.len(), b.serving.outcomes.len());
    for (x, y) in a.serving.outcomes.iter().zip(&b.serving.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token.to_bits(), y.first_token.to_bits());
        assert_eq!(x.finish.to_bits(), y.finish.to_bits());
    }
    assert_eq!(b.retries_scheduled, 0);
    assert_eq!(b.hedged, 0);
    assert_eq!(a.kv_xfer_time.to_bits(), b.kv_xfer_time.to_bits());
}
