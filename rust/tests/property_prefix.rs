//! Property tests for the fleet-wide prefix store (ISSUE 7
//! satellite), in the style of `property_kvcache.rs`: random
//! admit/extend/crash sequences against `hyperoffload::prefix::
//! PrefixStore` never break page conservation — per tier, the tracked
//! counters equal the per-run sums, page counts match token counts,
//! and no budget is exceeded after any rebalance. Instance
//! invalidation leaves no dangling non-host run, and a cluster run
//! with the cache disabled (`prefix: None`) is bit-identical to one
//! that never carried prefix metadata at all — the "PR 6 behavior is
//! untouched" guarantee behind the checked-in crossover numbers.

use hyperparallel::hyperoffload::policy::OffloadPolicy;
use hyperparallel::hyperoffload::prefix::{PrefixCacheConfig, PrefixStore, PrefixTier};
use hyperparallel::serving::{agentic_scenario, simulate_cluster, ClusterFabric, Request};
use hyperparallel::util::prop::{forall, pair_of, usize_in, vec_of, Check};

const FLEET: usize = 3;
const TOKENS_PER_PAGE: usize = 16;

fn small_cfg(hbm: usize, pool: usize, host: usize, enabled: bool) -> PrefixCacheConfig {
    let mut policy = OffloadPolicy::new(1 << 30);
    policy.hbm_reserve_frac = 0.25;
    policy.enabled = enabled;
    PrefixCacheConfig {
        hbm_pages_per_instance: hbm,
        pool_pages: pool,
        host_pages: host,
        host_bw: 8e9,
        policy,
    }
}

/// One random store operation:
/// (op selector, (tenant, (session, (tokens, instance)))).
type Op = (usize, (usize, (usize, (usize, usize))));

fn ops_gen(max_ops: usize) -> hyperparallel::util::prop::Gen<Vec<Op>> {
    vec_of(
        pair_of(
            usize_in(0, 9),
            pair_of(
                usize_in(0, 2),
                pair_of(
                    usize_in(0, 3),
                    pair_of(usize_in(1, 320), usize_in(0, FLEET - 1)),
                ),
            ),
        ),
        0,
        max_ops,
    )
}

/// Drive one op against the store the way the cluster does: admissions
/// pass the keys `lookup` reported as `used` (that is the only way the
/// cluster ever calls `admit`), completions extend the session run,
/// and a rare op crashes an instance.
fn apply(store: &mut PrefixStore, op: &Op) -> Result<(), String> {
    let &(sel, (tenant, (session, (tokens, instance)))) = op;
    let session = session as u64;
    match sel {
        // crash/release: every non-host run homed there must vanish
        0 => {
            store.invalidate_instance(instance);
            if store.runs_homed_at(instance) != 0 {
                return Err(format!("dangling runs at instance {instance} after crash"));
            }
        }
        // completion: history grows to prompt + output
        1 | 2 => {
            store.extend(tenant, session, tokens, instance);
        }
        // fresh admission: shared = what the workload would re-send
        _ => {
            let shared = if sel % 2 == 0 { tokens / 2 } else { 0 };
            let segs = store.lookup(tenant, session, shared);
            // the router signal must agree with the segments it is
            // derived from
            let want: usize = segs
                .iter()
                .filter(|s| s.tier == PrefixTier::Hbm && s.home == instance)
                .map(|s| s.pages)
                .sum();
            if store.local_hit_pages(tenant, session, shared, instance) != want {
                return Err("local_hit_pages disagrees with lookup".into());
            }
            let used: Vec<_> = segs.iter().map(|s| s.key).collect();
            store.admit(tenant, session, shared, tokens, instance, &used);
        }
    }
    store.check_conservation()
}

#[test]
fn prefix_store_conserves_pages_under_random_ops() {
    forall("prefix-conservation", 250, ops_gen(120), |ops| {
        // tight budgets so demotion chains fire constantly
        let mut store = PrefixStore::new(small_cfg(8, 12, 10, true), TOKENS_PER_PAGE);
        for (step, op) in ops.iter().enumerate() {
            if let Err(e) = apply(&mut store, op) {
                return Check::Fail(format!("step {step}: {e}"));
            }
        }
        Check::Pass
    });
}

#[test]
fn disabled_policy_conserves_by_evicting() {
    forall("prefix-conservation-disabled", 150, ops_gen(80), |ops| {
        let mut store = PrefixStore::new(small_cfg(8, 12, 10, false), TOKENS_PER_PAGE);
        for (step, op) in ops.iter().enumerate() {
            if let Err(e) = apply(&mut store, op) {
                return Check::Fail(format!("step {step}: {e}"));
            }
            // the disabled hierarchy never touches the lower tiers
            if store.pool_used() != 0 || store.host_used() != 0 {
                return Check::Fail(format!(
                    "step {step}: disabled policy spilled below HBM: pool {}, host {}",
                    store.pool_used(),
                    store.host_used()
                ));
            }
        }
        Check::Pass
    });
}

#[test]
fn crash_of_every_instance_leaves_only_host_runs() {
    forall("prefix-crash-dangling", 150, ops_gen(100), |ops| {
        let mut store = PrefixStore::new(small_cfg(8, 12, 10, true), TOKENS_PER_PAGE);
        for (step, op) in ops.iter().enumerate() {
            if let Err(e) = apply(&mut store, op) {
                return Check::Fail(format!("step {step}: {e}"));
            }
        }
        // total loss of the fleet: only host-tier runs may survive
        for inst in 0..FLEET {
            store.invalidate_instance(inst);
            if let Err(e) = store.check_conservation() {
                return Check::Fail(format!("after crash of {inst}: {e}"));
            }
            if store.runs_homed_at(inst) != 0 {
                return Check::Fail(format!("dangling runs at {inst}"));
            }
        }
        let survivors = store.run_count();
        if survivors > 0 && store.host_used() == 0 {
            return Check::Fail(format!(
                "{survivors} runs survived a full-fleet crash outside host memory"
            ));
        }
        if store.pool_used() != 0 {
            return Check::Fail("pooled pages survived the instances that leased them".into());
        }
        Check::Pass
    });
}

/// With `prefix: None` the session/shared-prefix request metadata is
/// inert: zeroing `shared_prefix_tokens` on every request changes
/// nothing about a cache-blind run. This is the compatibility
/// guarantee that keeps the checked-in crossover/autoscale numbers
/// (whose generators emit `shared_prefix_tokens: 0`) bit-identical to
/// their pre-prefix-cache values.
#[test]
fn cache_disabled_run_ignores_prefix_metadata_bit_identically() {
    let sc = agentic_scenario(ClusterFabric::Supernode, false);
    let reqs = sc.workload.generate(sc.horizon);
    assert!(
        reqs.iter().any(|r| r.shared_prefix_tokens > 0),
        "the agentic workload must actually carry shared prefixes"
    );
    let stripped: Vec<Request> = reqs
        .iter()
        .map(|r| Request {
            shared_prefix_tokens: 0,
            ..*r
        })
        .collect();
    let a = simulate_cluster(&sc.cluster, &reqs);
    let b = simulate_cluster(&sc.cluster, &stripped);
    assert_eq!(a.serving.outcomes, b.serving.outcomes, "outcome streams diverge");
    assert_eq!(a.serving.rejected, b.serving.rejected);
    assert_eq!(a.serving.prefill_tokens, b.serving.prefill_tokens);
    assert_eq!(a.serving.decoded_tokens, b.serving.decoded_tokens);
    assert_eq!(a.serving.makespan.to_bits(), b.serving.makespan.to_bits());
    assert_eq!(a.per_instance_completed, b.per_instance_completed);
    // and the blind run's prefix instrumentation is all zeros
    for rep in [&a, &b] {
        assert_eq!(rep.prefix_hits + rep.prefix_misses, 0);
        assert_eq!(rep.prefix_prompt_tokens, 0);
        assert_eq!(rep.prefix_fetch_time.to_bits(), 0.0f64.to_bits());
        assert_eq!(rep.tokens_recomputed_ratio(), 1.0);
        assert_eq!(rep.prefix_hit_rate(), 0.0);
    }
}
