//! Cluster serving scenario tests (ISSUE 3 acceptance): the
//! paper-shaped fabric crossover. On the long-prompt preset,
//! prefill/decode disaggregation sustains a strictly higher
//! max-QPS-under-p99-SLO operating point than colocation on the
//! supernode fabric (KV migration is near-free over pooled memory) and
//! a strictly lower one on the legacy RoCE-class fabric (the staged KV
//! copy steals decode iterations). Colocation never touches the
//! fabric, so its operating point is bit-identical across fabrics —
//! migration cost is provably the deciding term.
//!
//! The bounds asserted here are mirrored (more loosely) by the CI
//! regression gate: `benches/bench_serving.rs` emits the same
//! deterministic virtual-time metrics into `BENCH_serving.json`, and
//! `tools/bench_regression.py` compares them against
//! `BENCH_baseline.json`. Green tests imply a green gate.

use hyperparallel::serving::{
    cluster_rate_sweep, cluster_slo, crossover_comparison, crossover_scenario,
    run_cluster_scenario, ClusterFabric, ClusterMode, CLUSTER_RATES,
};
use hyperparallel::sim::tags;

#[test]
fn fabric_decides_the_disaggregation_crossover() {
    let s = crossover_comparison();

    // Supernode: disaggregation wins (acceptance bound 1.10x; the
    // preset lands ~1.33x — colocated 60 vs disaggregated 80).
    assert!(
        s.disagg_supernode.rate >= 1.10 * s.colocated_supernode.rate,
        "disaggregation must win on the supernode fabric: {} vs {}",
        s.disagg_supernode.rate,
        s.colocated_supernode.rate
    );
    assert!(
        s.disagg_supernode.rate >= 70.0,
        "supernode disaggregated operating point too low: {}",
        s.disagg_supernode.rate
    );
    assert!(
        s.colocated_supernode.rate >= 40.0,
        "colocated operating point too low: {}",
        s.colocated_supernode.rate
    );

    // Legacy: colocation wins (acceptance bound: colocated >=
    // disaggregated; the preset lands ~3x — 60 vs 20).
    assert!(
        s.colocated_legacy.rate >= s.disagg_legacy.rate,
        "colocation must win on the legacy fabric: {} vs {}",
        s.colocated_legacy.rate,
        s.disagg_legacy.rate
    );
    assert!(
        s.colocated_legacy.rate >= 1.5 * s.disagg_legacy.rate,
        "the legacy gap should be decisive: {} vs {}",
        s.colocated_legacy.rate,
        s.disagg_legacy.rate
    );

    // Colocation never migrates, so the fabric cannot move its
    // operating point: the crossover is entirely the migration term.
    assert_eq!(
        s.colocated_supernode.rate, s.colocated_legacy.rate,
        "colocated operating point must be fabric-independent"
    );
    assert_eq!(
        s.colocated_supernode.p99_ttft.to_bits(),
        s.colocated_legacy.p99_ttft.to_bits(),
        "colocated runs must be bit-identical across fabrics"
    );

    // Every winning operating point actually met the SLO cleanly.
    let slo = cluster_slo();
    for op in [
        &s.colocated_supernode,
        &s.disagg_supernode,
        &s.colocated_legacy,
        &s.disagg_legacy,
    ] {
        assert!(op.attains_slo);
        assert_eq!(op.rejected, 0);
        assert!(op.p99_ttft <= slo.ttft_p99);
        assert!(op.p99_tpot <= slo.tpot_p99);
    }
}

#[test]
fn crossover_sweep_is_deterministic_and_composed() {
    let sc = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
    let slo = cluster_slo();
    let a = cluster_rate_sweep(&sc, &CLUSTER_RATES[..3], &slo);
    let b = cluster_rate_sweep(&sc, &CLUSTER_RATES[..3], &slo);
    assert_eq!(a.len(), 3);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.rate, y.rate);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.p99_ttft.to_bits(), y.p99_ttft.to_bits());
        assert_eq!(x.p99_tpot.to_bits(), y.p99_tpot.to_bits());
    }

    // the cluster trace is a first-class indexed SimResult: four
    // instance resources, prefill work disjoint from decode work, and
    // kv_xfer staged on the decode engines only
    let rep = run_cluster_scenario(&sc);
    let trace = &rep.serving.trace;
    assert_eq!(trace.resources, 4);
    assert!(trace.tagged_count(tags::KV_XFER) > 0);
    assert!(trace.tagged_count(tags::PREFILL) > 0);
    assert!(trace.tagged_count(tags::DECODE) > 0);
    for iv in trace.intervals_tagged(tags::KV_XFER) {
        assert!(
            iv.resource.0 >= 2,
            "instances 0/1 are the prefill pool; xfer lands on decode engines"
        );
    }
    for iv in trace.intervals_tagged(tags::PREFILL) {
        assert!(iv.resource.0 < 2, "prefill work stays in the prefill pool");
    }
    assert_eq!(rep.kv_migrations as usize, rep.completed());
    assert!(rep.kv_bytes_migrated > 0.0);
}

#[test]
fn disaggregated_overload_backpressures_instead_of_dropping() {
    // far past the legacy operating point: parked pages throttle the
    // prefill pool, nothing is dropped, and every request still
    // completes — the SLO failure mode is latency, not loss
    let mut sc = crossover_scenario(ClusterFabric::Legacy, ClusterMode::Disaggregated);
    sc.workload.arrival = sc.workload.arrival.with_mean_rate(80.0);
    let submitted = sc.workload.generate(sc.horizon).len();
    let rep = run_cluster_scenario(&sc);
    assert_eq!(rep.completed() + rep.serving.rejected as usize, submitted);
    assert_eq!(rep.serving.rejected, 0, "backpressure, not loss");
    assert_eq!(rep.kv_migrations as usize, rep.completed());
    let slo = cluster_slo();
    let op = rep.operating_point(80.0, &slo);
    assert!(!op.attains_slo, "80 req/s must blow the SLO on legacy");
    assert!(
        op.p99_ttft > slo.ttft_p99 || op.p99_tpot > slo.tpot_p99,
        "failure shows up as latency"
    );
}
