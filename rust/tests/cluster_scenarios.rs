//! Cluster serving scenario tests (ISSUE 3 acceptance): the
//! paper-shaped fabric crossover. On the long-prompt preset,
//! prefill/decode disaggregation sustains a strictly higher
//! max-QPS-under-p99-SLO operating point than colocation on the
//! supernode fabric (KV migration is near-free over pooled memory) and
//! a strictly lower one on the legacy RoCE-class fabric (the staged KV
//! copy steals decode iterations). Colocation never touches the
//! fabric, so its operating point is bit-identical across fabrics —
//! migration cost is provably the deciding term.
//!
//! The bounds asserted here are mirrored (more loosely) by the CI
//! regression gate: `benches/bench_serving.rs` emits the same
//! deterministic virtual-time metrics into `BENCH_serving.json`, and
//! `tools/bench_regression.py` compares them against
//! `BENCH_baseline.json`. Green tests imply a green gate.

use hyperparallel::serving::{
    cluster_rate_sweep, cluster_slo, crossover_comparison, crossover_scenario,
    run_cluster_scenario, ClusterFabric, ClusterMode, CLUSTER_RATES,
};
use hyperparallel::sim::tags;

#[test]
fn fabric_decides_the_disaggregation_crossover() {
    let s = crossover_comparison();

    // Supernode: disaggregation wins (acceptance bound 1.10x; the
    // preset lands ~1.33x — colocated 60 vs disaggregated 80).
    assert!(
        s.disagg_supernode.rate >= 1.10 * s.colocated_supernode.rate,
        "disaggregation must win on the supernode fabric: {} vs {}",
        s.disagg_supernode.rate,
        s.colocated_supernode.rate
    );
    assert!(
        s.disagg_supernode.rate >= 70.0,
        "supernode disaggregated operating point too low: {}",
        s.disagg_supernode.rate
    );
    assert!(
        s.colocated_supernode.rate >= 40.0,
        "colocated operating point too low: {}",
        s.colocated_supernode.rate
    );

    // Legacy: colocation wins (acceptance bound: colocated >=
    // disaggregated; the preset lands ~3x — 60 vs 20).
    assert!(
        s.colocated_legacy.rate >= s.disagg_legacy.rate,
        "colocation must win on the legacy fabric: {} vs {}",
        s.colocated_legacy.rate,
        s.disagg_legacy.rate
    );
    assert!(
        s.colocated_legacy.rate >= 1.5 * s.disagg_legacy.rate,
        "the legacy gap should be decisive: {} vs {}",
        s.colocated_legacy.rate,
        s.disagg_legacy.rate
    );

    // Colocation never migrates, so the fabric cannot move its
    // operating point: the crossover is entirely the migration term.
    assert_eq!(
        s.colocated_supernode.rate, s.colocated_legacy.rate,
        "colocated operating point must be fabric-independent"
    );
    assert_eq!(
        s.colocated_supernode.p99_ttft.to_bits(),
        s.colocated_legacy.p99_ttft.to_bits(),
        "colocated runs must be bit-identical across fabrics"
    );

    // Every winning operating point actually met the SLO cleanly.
    let slo = cluster_slo();
    for op in [
        &s.colocated_supernode,
        &s.disagg_supernode,
        &s.colocated_legacy,
        &s.disagg_legacy,
    ] {
        assert!(op.attains_slo);
        assert_eq!(op.rejected, 0);
        assert!(op.p99_ttft <= slo.ttft_p99);
        assert!(op.p99_tpot <= slo.tpot_p99);
    }
}

#[test]
fn crossover_sweep_is_deterministic_and_composed() {
    let sc = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
    let slo = cluster_slo();
    let a = cluster_rate_sweep(&sc, &CLUSTER_RATES[..3], &slo);
    let b = cluster_rate_sweep(&sc, &CLUSTER_RATES[..3], &slo);
    assert_eq!(a.len(), 3);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.rate, y.rate);
        assert_eq!(x.completed, y.completed);
        assert_eq!(x.p99_ttft.to_bits(), y.p99_ttft.to_bits());
        assert_eq!(x.p99_tpot.to_bits(), y.p99_tpot.to_bits());
    }

    // the cluster trace is a first-class indexed SimResult: four
    // instance resources, prefill work disjoint from decode work, and
    // kv_xfer staged on the decode engines only
    let rep = run_cluster_scenario(&sc);
    let trace = &rep.serving.trace;
    assert_eq!(trace.resources(), 4);
    assert!(trace.tagged_count(tags::KV_XFER) > 0);
    assert!(trace.tagged_count(tags::PREFILL) > 0);
    assert!(trace.tagged_count(tags::DECODE) > 0);
    for iv in trace.intervals_tagged(tags::KV_XFER) {
        assert!(
            iv.resource.0 >= 2,
            "instances 0/1 are the prefill pool; xfer lands on decode engines"
        );
    }
    for iv in trace.intervals_tagged(tags::PREFILL) {
        assert!(iv.resource.0 < 2, "prefill work stays in the prefill pool");
    }
    assert_eq!(rep.kv_migrations as usize, rep.completed());
    assert!(rep.kv_bytes_migrated > 0.0);
}

#[test]
fn disaggregated_overload_backpressures_instead_of_dropping() {
    // far past the legacy operating point: parked pages throttle the
    // prefill pool, nothing is dropped, and every request still
    // completes — the SLO failure mode is latency, not loss
    let mut sc = crossover_scenario(ClusterFabric::Legacy, ClusterMode::Disaggregated);
    sc.workload.arrival = sc.workload.arrival.with_mean_rate(80.0);
    let submitted = sc.workload.generate(sc.horizon).len();
    let rep = run_cluster_scenario(&sc);
    assert_eq!(rep.completed() + rep.serving.rejected as usize, submitted);
    assert_eq!(rep.serving.rejected, 0, "backpressure, not loss");
    assert_eq!(rep.kv_migrations as usize, rep.completed());
    let slo = cluster_slo();
    let op = rep.operating_point(80.0, &slo);
    assert!(!op.attains_slo, "80 req/s must blow the SLO on legacy");
    assert!(
        op.p99_ttft > slo.ttft_p99 || op.p99_tpot > slo.tpot_p99,
        "failure shows up as latency"
    );
}

// ---- ISSUE 4: elastic autoscaling + instance-failure recovery ---------

use hyperparallel::serving::{
    autoscale_comparison, autoscale_crash_scenario, autoscale_scenario, autoscale_slo,
    autoscale_workload, simulate_cluster, AutoscaleConfig, AutoscalePolicy, ClusterConfig,
    CostModel, InstanceCrash, InstanceRole, InstanceSpec, LengthDist, RoutePolicy, WorkloadConfig,
    AUTOSCALE_MEAN_RATE, AUTOSCALE_PERIOD,
};
use hyperparallel::serving::{spread_placement, ArrivalProcess};
use hyperparallel::faults::{FaultPlan, LinkDegrade, RetryPolicy};
use hyperparallel::hyperoffload::kvcache::KvCacheConfig;
use hyperparallel::supernode::{LinkTier, Topology};
use std::collections::BTreeSet;

/// The ISSUE 4 acceptance scenario: across a ≥4x diurnal swing, the
/// elastic cluster holds the p99 TTFT SLO with ≥25% fewer
/// instance-seconds than static peak provisioning on the supernode
/// fabric — and the *same* policy blows the SLO on the legacy fabric,
/// because the model-load warm-up (16 GiB over the fabric) is ~88 ms
/// on pooled UB memory and ~1.4 s over RoCE. Expected values (seed
/// 42, mirrored by tools/cluster_simcheck.py): static p99 ≈ 0.156 s,
/// elastic p99 ≈ 0.251 s, saving ≈ 32.9%, legacy elastic ≈ 1.08 s.
#[test]
fn elastic_scaling_meets_slo_with_fewer_instance_hours_on_supernode_only() {
    let wl = autoscale_workload(AUTOSCALE_MEAN_RATE);
    assert!(
        wl.arrival.swing_ratio(AUTOSCALE_PERIOD, 4800) >= 4.0,
        "the diurnal preset must swing at least 4x"
    );
    let submitted = wl.generate(AUTOSCALE_PERIOD).len();
    let slo = autoscale_slo();

    let sn = autoscale_comparison(ClusterFabric::Supernode);
    let sop = sn.static_report.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert!(
        sop.attains_slo,
        "static peak provisioning must attain: p99 ttft {}",
        sop.p99_ttft
    );
    assert_eq!(sn.static_report.scale_ups, 0);
    assert_eq!(sn.static_report.crashes, 0);

    let eop = sn.elastic_report.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert_eq!(eop.rejected, 0, "elastic scaling must not shed load");
    assert!(
        eop.p99_ttft <= slo.ttft_p99,
        "elastic must hold the TTFT SLO on the supernode fabric: {}",
        eop.p99_ttft
    );
    assert!(eop.attains_slo);
    assert_eq!(
        sn.elastic_report.completed() + sn.elastic_report.serving.rejected as usize,
        submitted
    );
    // the policy really tracked the swing, in both directions
    assert!(sn.elastic_report.scale_ups >= 5, "{}", sn.elastic_report.scale_ups);
    assert!(sn.elastic_report.scale_downs >= 5);
    assert_eq!(
        sn.elastic_report.serving.trace.tagged_count(tags::WARMUP) as u64,
        sn.elastic_report.scale_ups,
        "every scale-up pays a model-load warm-up interval"
    );
    assert!(sn.elastic_report.serving.trace.tagged_count(tags::DRAIN) >= 1);
    assert!(sn.elastic_report.warmup_time > 0.0);

    // the headline: ≥25% fewer instance-seconds than static peak
    let saved = sn.instance_seconds_saved();
    assert!(
        saved >= 0.25,
        "instance-second saving {saved:.3} below the 25% gate \
         (elastic {:.1} vs static {:.1})",
        sn.elastic_report.instance_seconds,
        sn.static_report.instance_seconds
    );

    // same policy, legacy fabric: the 1.4 s warm-up lag blows the SLO
    let lg = run_cluster_scenario(&autoscale_scenario(ClusterFabric::Legacy, true));
    let lop = lg.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    assert_eq!(lop.rejected, 0);
    assert!(
        lop.p99_ttft > slo.ttft_p99,
        "legacy warm-up lag must blow the TTFT SLO: {}",
        lop.p99_ttft
    );
}

/// An injected crash at peak traffic loses zero requests — every
/// request is completed (nothing is even rejected: the survivors and
/// the instant replacement absorb the requeues) — and the cluster
/// re-converges to SLO attainment for requests arriving after the
/// recovery window. Mirror values: whole-run p99 ≈ 0.37 s, post-crash
/// window p99 ≈ 0.27 s.
#[test]
fn instance_crash_loses_zero_requests_and_reconverges_to_slo() {
    let sc = autoscale_crash_scenario(ClusterFabric::Supernode);
    let submitted = sc.workload.generate(sc.horizon).len();
    let rep = run_cluster_scenario(&sc);
    let slo = autoscale_slo();

    assert_eq!(rep.crashes, 1);
    assert!(rep.crash_requeues > 0, "the victim held in-flight work");
    assert_eq!(
        rep.completed() + rep.serving.rejected as usize,
        submitted,
        "conservation: completed + rejected must cover every request"
    );
    assert_eq!(rep.serving.rejected, 0, "zero requests lost to the crash");
    let ids: BTreeSet<u64> = rep.serving.outcomes.iter().map(|o| o.id).collect();
    assert_eq!(ids.len(), rep.completed(), "no duplicate completions");

    // the crash is visible in the indexed trace, and the autoscaler
    // replaced the dead instance
    assert_eq!(rep.serving.trace.tagged_count(tags::CRASH), 1);
    assert!(rep.scale_ups >= 1);

    // even with the crash inside the run, the whole-run p99 holds...
    assert!(
        rep.serving.ttft_pct(99.0) <= slo.ttft_p99,
        "whole-run p99 {}",
        rep.serving.ttft_pct(99.0)
    );
    // ...and requests arriving 2 s after the crash meet the SLO again
    let crash_t = AUTOSCALE_PERIOD * 0.5;
    let reconv = rep
        .serving
        .ttft_pct_arriving_in(99.0, crash_t + 2.0, AUTOSCALE_PERIOD);
    assert!(
        reconv <= slo.ttft_p99,
        "post-crash arrivals must re-converge to the SLO: {reconv}"
    );
}

// ---- ISSUE 4 satellite: request conservation across the grid ----------

fn grid_device() -> KvCacheConfig {
    KvCacheConfig {
        kv_bytes_per_token: 1024,
        tokens_per_page: 16,
        weight_bytes: 1 << 20,
        hbm_usable: (1 << 20) + 64 * 16 * 1024,
        hbm_bw: 1.6e12,
        pool_bw: 392e9,
        attn_tokens_per_s: 40e6,
    }
}

/// A fault plan sized to the 0.5 s grid runs: every non-local tier
/// degraded hard over the middle of the window, with a retry policy
/// whose timeout is tight enough that migrations inside the window
/// actually park and re-route (the machinery conservation must hold
/// under, not around).
fn grid_faults() -> (FaultPlan, RetryPolicy) {
    let mut plan = FaultPlan::empty();
    for tier in [LinkTier::Board, LinkTier::Rack, LinkTier::CrossRack] {
        plan.link_windows.push(LinkDegrade {
            tier,
            start: 0.1,
            end: 0.3,
            bandwidth_scale: 0.001,
            latency_scale: 10.0,
        });
    }
    let retry = RetryPolicy {
        timeout: 1e-5,
        backoff: 1e-5,
        max_attempts: 2,
        hedge: 2.0,
    };
    (plan, retry)
}

fn grid_cluster(disagg: bool, route: RoutePolicy, inject: bool, faulted: bool) -> ClusterConfig {
    let topology = Topology::matrix384();
    let places = spread_placement(&topology, 8);
    let instances = if disagg {
        vec![
            InstanceSpec { device: places[0], role: InstanceRole::Prefill, slots: 2 },
            InstanceSpec { device: places[1], role: InstanceRole::Prefill, slots: 2 },
            InstanceSpec { device: places[2], role: InstanceRole::Decode, slots: 4 },
            InstanceSpec { device: places[3], role: InstanceRole::Decode, slots: 4 },
        ]
    } else {
        places[..3]
            .iter()
            .map(|&device| InstanceSpec {
                device,
                role: InstanceRole::Colocated,
                slots: 3,
            })
            .collect()
    };
    let autoscale = inject.then(|| AutoscaleConfig {
        policy: AutoscalePolicy::QueueDepth {
            scale_up_backlog: 0.8,
            scale_down_backlog: 0.7,
        },
        eval_interval: 0.02,
        min_instances: 1,
        max_instances: 5,
        slots: 3,
        up_cooldown: 0.0,
        down_cooldown: 0.05,
        lookback: 0.5,
        device_pool: places[4..8].to_vec(),
    });
    let failures = if inject {
        vec![
            InstanceCrash { time: 0.08, instance: 0 },
            InstanceCrash { time: 0.2, instance: 1 },
        ]
    } else {
        vec![]
    };
    let (faults, retry) = if faulted {
        let (p, r) = grid_faults();
        (p, Some(r))
    } else {
        (FaultPlan::empty(), None)
    };
    let mut b = ClusterConfig::builder(topology, instances, CostModel::new(grid_device(), 0.0))
        .max_seq(512)
        .route(route)
        .failures(failures)
        .faults(faults);
    if let Some(aus) = autoscale {
        b = b.autoscale(aus);
    }
    if let Some(r) = retry {
        b = b.retry(r);
    }
    b.build()
}

/// Property: across the full router-policy × cluster-mode × seed grid
/// — with and without crashes, elastic scale-downs, and a fault plan
/// (degraded links + retry/hedge machinery) injected — every generated
/// request is completed or rejected exactly once, never lost or
/// duplicated.
#[test]
fn request_conservation_across_policy_mode_seed_grid() {
    let policies = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstandingKv,
        RoutePolicy::SessionAffinity,
        // without a prefix store, CacheAware must degrade to session
        // affinity and conserve identically
        RoutePolicy::CacheAware,
    ];
    for disagg in [false, true] {
        for &route in &policies {
            for seed in [1u64, 2, 3] {
                for (inject, faulted) in [(false, false), (true, false), (false, true), (true, true)]
                {
                    let wl = WorkloadConfig {
                        arrival: ArrivalProcess::Poisson { rate: 400.0 },
                        prompt: LengthDist::Uniform { lo: 24, hi: 72 },
                        output: LengthDist::Uniform { lo: 6, hi: 18 },
                        seed,
                    };
                    let reqs = wl.generate(0.5);
                    let cfg = grid_cluster(disagg, route, inject, faulted);
                    let rep = simulate_cluster(&cfg, &reqs);
                    let cell = format!(
                        "disagg={disagg} route={route:?} seed={seed} inject={inject} faulted={faulted}"
                    );
                    let ids: BTreeSet<u64> =
                        rep.serving.outcomes.iter().map(|o| o.id).collect();
                    assert_eq!(
                        ids.len(),
                        rep.completed(),
                        "{cell}: duplicate completions"
                    );
                    assert!(
                        ids.iter().all(|&id| id < reqs.len() as u64),
                        "{cell}: unknown request id completed"
                    );
                    assert_eq!(
                        rep.completed() as u64 + rep.serving.rejected,
                        reqs.len() as u64,
                        "{cell}: requests lost or double-counted"
                    );
                    if inject {
                        assert_eq!(rep.crashes, 2, "{cell}: both crashes must land");
                    } else {
                        assert_eq!(rep.crashes, 0);
                        assert_eq!(rep.scale_ups, 0);
                    }
                }
            }
        }
    }
}

// ---- ISSUE 7 acceptance: the agentic prefix-cache gate ----------------
//
// On the checked-in seed-42 agentic multi-turn scenario, cache-aware
// routing + the fleet-wide prefix store beat cache-blind session
// affinity by >= 1.3x max-QPS-under-SLO with <= 0.5x the recomputed
// tokens on the supernode fabric, and the gap collapses on legacy
// RoCE where a host-tier fetch loses the bandwidth race against
// recompute. tools/cluster_simcheck.py mirrors these cells
// bit-identically (supernode 60 vs 40 QPS, ratio 0.140; legacy 50 vs
// 40, ratio 0.500).

use hyperparallel::serving::agentic_comparison;

#[test]
fn prefix_cache_lifts_agentic_qps_on_supernode_fabric() {
    let s = agentic_comparison(ClusterFabric::Supernode);

    assert!(
        s.qps_gain() >= 1.3,
        "cache-aware must win >= 1.3x on supernode: {} vs {}",
        s.aware.rate,
        s.blind.rate
    );
    assert!(s.aware.rate >= 55.0, "aware operating point too low: {}", s.aware.rate);

    let ratio = s.aware_report.tokens_recomputed_ratio();
    assert!(ratio <= 0.5, "recomputed-token ratio too high: {ratio}");
    assert!(
        s.aware_report.prefix_hit_rate() >= 0.9,
        "agentic sessions must hit the cache: {}",
        s.aware_report.prefix_hit_rate()
    );
    // the supernode path actually exercises the tier chain: histories
    // overflow the tiny HBM carve-out into pooled DRAM and promote
    // back on reuse, and the engine pays real (but winning) fetch time
    assert!(s.aware_report.prefix_demotions > 0, "HBM carve-out must overflow");
    assert!(s.aware_report.prefix_promotions > 0, "reuse must promote runs back");
    assert!(s.aware_report.prefix_fetch_time > 0.0);

    // cache-blind session affinity recomputes everything by
    // construction: no store, no hits, ratio exactly 1.0
    assert_eq!(s.blind_report.tokens_recomputed_ratio(), 1.0);
    assert_eq!(
        s.blind_report.prefix_hits + s.blind_report.prefix_misses,
        0,
        "the blind cell must not consult a store"
    );
}

#[test]
fn prefix_cache_gain_collapses_on_legacy_fabric() {
    let sn = agentic_comparison(ClusterFabric::Supernode);
    let lg = agentic_comparison(ClusterFabric::Legacy);

    // no pooled tier + 8 GB/s host fetches: the cache still dedups
    // pages, but fetches lose to recompute and the QPS edge shrinks
    assert!(
        lg.qps_gain() < sn.qps_gain(),
        "legacy gain {} must trail supernode gain {}",
        lg.qps_gain(),
        sn.qps_gain()
    );
    assert!(lg.qps_gain() < 1.3, "legacy gain must fall below the supernode gate");
    assert!(
        lg.aware_report.tokens_recomputed_ratio() > sn.aware_report.tokens_recomputed_ratio(),
        "legacy must recompute more: {} vs {}",
        lg.aware_report.tokens_recomputed_ratio(),
        sn.aware_report.tokens_recomputed_ratio()
    );
    // without pooled DRAM nothing is ever promoted back over the
    // fabric — demotions go straight to host and stay there
    assert_eq!(lg.aware_report.prefix_promotions, 0);
    // the blind cells never touch the fabric or the store, so they are
    // bit-identical across fabrics
    assert_eq!(
        sn.blind_report.serving.outcomes, lg.blind_report.serving.outcomes,
        "cache-blind colocated runs must not depend on the fabric"
    );
}
