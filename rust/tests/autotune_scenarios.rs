//! Acceptance scenarios for the ISSUE 10 auto-tuner: on every
//! checked-in seed-42 scenario — the PR 5/9 co-scheduled training pool
//! and the PR 9 mixed-generation and slow-rack fleets — the
//! generate → prune → simulate → refine search must match or beat each
//! hand-written preset lease, within the 256-candidate default budget.

use hyperparallel::hypermpmd::{cosched_train_job, COSCHED_POOL_DEVICES, FLEET_SLOW_RACK_DERATE};
use hyperparallel::hypershard::{autotune, AutoTuneConfig, ElasticObjective, TuneReport};
use hyperparallel::supernode::{DeviceId, DeviceSpec, Fabric, Fleet, Geometry, Topology};

/// The co-scheduled training pool as a single-pool fleet: the same
/// 32-device supernode shape the PR 9 fleet presets carve their pools
/// from, sized to `COSCHED_POOL_DEVICES`.
fn cosched_pool_fleet() -> Fleet {
    let topo = Topology::new(
        Geometry {
            racks: 4,
            boards_per_rack: 1,
            dies_per_board: 8,
        },
        Fabric::supernode(),
        DeviceSpec::ascend_910c(),
    );
    assert_eq!(topo.device_count(), COSCHED_POOL_DEVICES);
    Fleet::single(topo)
}

/// Run the tuner and check the ledger: budget respected (the
/// acceptance bound is <= 256 simulated candidates), a best row
/// present, and the best simulated cost no worse than every preset.
fn assert_beats_presets(report: &TuneReport, presets: &[(&str, f64)]) -> f64 {
    assert!(
        report.simulated <= report.budget,
        "simulated {} candidates, budget {}",
        report.simulated,
        report.budget
    );
    assert!(report.budget <= 256, "default budget drifted past 256");
    let best = report.best().expect("tuner found no feasible candidate");
    for (name, cost) in presets {
        assert!(
            best.simulated <= cost * (1.0 + 1e-9),
            "tuned '{}' ({:.6}s) is worse than preset '{name}' ({cost:.6}s)",
            best.label,
            best.simulated
        );
    }
    best.simulated
}

#[test]
fn tuner_matches_or_beats_cosched_pool_presets() {
    let fleet = cosched_pool_fleet();
    let job = cosched_train_job();
    // hand-written leases from the co-scheduling scenario: the full
    // 32-device broker lease and the 16-device static-partition share
    let full = job.step_time_fleet(&fleet, &fleet.all_devices(), true);
    let half_group: Vec<DeviceId> = (0..COSCHED_POOL_DEVICES / 2).map(DeviceId).collect();
    let half = job.step_time_fleet(&fleet, &half_group, true);

    let obj = ElasticObjective::new(job, fleet, true);
    let report = autotune(&obj, &AutoTuneConfig::default());
    let best = assert_beats_presets(&report, &[("full lease", full), ("static half", half)]);
    // the pool is homogeneous: nothing can beat the full lease, so the
    // tuner must land exactly on the preset cost
    assert_eq!(best.to_bits(), full.to_bits(), "homogeneous pool optimum");
}

#[test]
fn tuner_matches_or_beats_mixed_generation_presets() {
    let fleet = Fleet::mixed_generations();
    let job = cosched_train_job();
    let all = fleet.all_devices();
    let aware_full = job.step_time_fleet(&fleet, &all, true);
    let naive_full = job.step_time_fleet(&fleet, &all, false);
    let fast_pool = job.step_time_fleet(&fleet, &fleet.pool_devices(0), true);

    let obj = ElasticObjective::new(job, fleet, true);
    let report = autotune(&obj, &AutoTuneConfig::default());
    assert_beats_presets(
        &report,
        &[
            ("aware full fleet", aware_full),
            ("naive full fleet", naive_full),
            ("910c pool only", fast_pool),
        ],
    );
}

#[test]
fn tuner_matches_or_beats_slow_rack_presets() {
    let fleet = Fleet::slow_rack(FLEET_SLOW_RACK_DERATE);
    let job = cosched_train_job();
    let all = fleet.all_devices();
    let aware_full = job.step_time_fleet(&fleet, &all, true);
    let naive_full = job.step_time_fleet(&fleet, &all, false);

    let obj = ElasticObjective::new(job, fleet, true);
    let report = autotune(&obj, &AutoTuneConfig::default());
    let best = assert_beats_presets(
        &report,
        &[
            ("aware full fleet", aware_full),
            ("naive full fleet", naive_full),
        ],
    );
    // the throttled rack drags the naive plan: the tuned lease must
    // strictly beat it, not just tie
    assert!(best < naive_full, "tuner failed to dodge the slow rack");
}
