//! Failure injection: stragglers, degraded links, memory pressure.
//! The framework must degrade gracefully, never deadlock or corrupt
//! accounting.

use hyperparallel::collectives;
use hyperparallel::graph::CollectiveKind;
use hyperparallel::hypermpmd::{
    schedule_dynamic, schedule_static, OmniModalWorkload, SubModule,
};
use hyperparallel::memory::{AllocError, MemoryHierarchy, TransferEngine};
use hyperparallel::supernode::{DeviceId, DeviceSpec, Fabric, Geometry, Topology};
use hyperparallel::util::prop::{forall, usize_in, vec_of, Check};
use hyperparallel::util::rng::Rng;

/// A straggling sub-module (3x slower) hurts the static pipeline far
/// more than the dynamic scheduler.
#[test]
fn straggler_submodule_hurts_static_more() {
    let mk = |slow: f64| OmniModalWorkload {
        modules: vec![
            SubModule { name: "a".into(), time_per_microbatch: 30e-3, inputs: vec![] },
            SubModule { name: "b".into(), time_per_microbatch: 30e-3 * slow, inputs: vec![] },
            SubModule { name: "c".into(), time_per_microbatch: 30e-3, inputs: vec![] },
            SubModule { name: "fuse".into(), time_per_microbatch: 20e-3, inputs: vec![0, 1, 2] },
        ],
        microbatches: 16,
    };
    let healthy_gain = {
        let w = mk(1.0);
        schedule_static(&w).makespan / schedule_dynamic(&w, 4).makespan
    };
    let degraded_gain = {
        let w = mk(3.0);
        schedule_static(&w).makespan / schedule_dynamic(&w, 4).makespan
    };
    assert!(
        degraded_gain > healthy_gain,
        "degraded {degraded_gain} <= healthy {healthy_gain}"
    );
}

/// Link degradation: cutting cross-rack bandwidth must increase every
/// collective's cost monotonically, and never panic.
#[test]
fn degraded_links_raise_collective_costs_monotonically() {
    let group: Vec<DeviceId> = (0..96).map(DeviceId).collect();
    let mut prev = 0.0;
    for cut in [1.0, 0.5, 0.25, 0.1, 0.01] {
        let mut fabric = Fabric::supernode();
        fabric.cross_rack.bandwidth *= cut;
        fabric.rack.bandwidth *= cut;
        let topo = Topology::new(
            Geometry { racks: 4, boards_per_rack: 4, dies_per_board: 8 },
            fabric,
            DeviceSpec::ascend_910c(),
        );
        let t = collectives::cost(&topo, CollectiveKind::AllReduce, 1e8, &group).time;
        assert!(t >= prev, "cost decreased under degradation");
        prev = t;
    }
}

/// HBM pressure: pathological alloc patterns must end in typed errors,
/// never panics or accounting drift.
#[test]
fn hbm_pressure_yields_errors_not_panics() {
    let mut m = MemoryHierarchy::new(16 * 4096, 1 << 20, TransferEngine::supernode());
    let mut live = Vec::new();
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let bytes = 4096 * rng.range(1, 6) as u64;
        match m.register_in_hbm(bytes) {
            Ok(id) => live.push(id),
            Err(AllocError::OutOfMemory { .. }) | Err(AllocError::Fragmented { .. }) => {
                // evict by releasing a random region (simulates policy)
                if !live.is_empty() {
                    let i = rng.range(0, live.len());
                    m.release(live.swap_remove(i));
                }
            }
        }
        m.check_invariants().unwrap();
    }
    for id in live {
        m.release(id);
    }
    assert_eq!(m.hbm_used(), 0);
}

/// Offload under total HBM exhaustion with everything pinned: the
/// eviction loop must return OutOfMemory, not spin.
#[test]
fn fully_pinned_hbm_reports_oom() {
    let mut m = MemoryHierarchy::new(8 * 4096, 1 << 20, TransferEngine::supernode());
    let a = m.register_in_dram(4 * 4096).unwrap();
    let b = m.register_in_dram(4 * 4096).unwrap();
    m.prefetch(a).unwrap();
    m.prefetch(b).unwrap();
    m.pin(a, true);
    m.pin(b, true);
    assert!(matches!(
        m.evict_until(4096, false),
        Err(AllocError::OutOfMemory { .. })
    ));
    m.check_invariants().unwrap();
}

/// Random DAGs through the simulator must always complete (no deadlock)
/// and respect the critical-path lower bound.
#[test]
fn prop_random_dags_never_deadlock() {
    forall(
        "sim-no-deadlock",
        60,
        vec_of(usize_in(0, 4), 2, 80),
        |durations| {
            use hyperparallel::sim::Engine;
            let mut e = Engine::new();
            let rs: Vec<_> = (0..4).map(|i| e.add_resource(format!("r{i}"))).collect();
            let mut rng = Rng::new(durations.len() as u64 * 31);
            let mut tasks = Vec::new();
            for (i, &d) in durations.iter().enumerate() {
                // random backward deps (valid DAG by construction)
                let mut deps = Vec::new();
                if i > 0 {
                    for _ in 0..rng.range(0, 3.min(i)) {
                        deps.push(tasks[rng.range(0, i)]);
                    }
                    deps.dedup();
                }
                tasks.push(e.add_task(rs[i % 4], d as f64 * 0.001, &deps, 0));
            }
            let res = e.run();
            let total: f64 = durations.iter().map(|&d| d as f64 * 0.001).sum();
            Check::from_bool(
                res.makespan <= total + 1e-9 && res.intervals.len() == durations.len(),
                &format!("makespan {} vs serial {}", res.makespan, total),
            )
        },
    );
}

/// Degenerate process-group configs: empty, reversed, out of range —
/// rejected with typed errors.
#[test]
fn malformed_process_groups_rejected() {
    use hyperparallel::hypermpmd::{MappingError, ProcessGroupMap};
    let cases = [
        (r#"{"groups": []}"#, true), // empty is fine
        (r#"{"groups": [{"name":"a","module":"m","ranks":[8,4]}]}"#, false),
        (r#"{"groups": [{"name":"a","module":"m","ranks":[0]}]}"#, false),
        (r#"not json"#, false),
    ];
    for (src, ok) in cases {
        let r = ProcessGroupMap::from_json(src, 16);
        assert_eq!(r.is_ok(), ok, "{src}: {r:?}");
        if let Err(e) = r {
            // Display impl must not panic
            let _ = format!("{e}");
            let _: &dyn std::error::Error = &e;
            match e {
                MappingError::Parse(_)
                | MappingError::BadRange { .. }
                | MappingError::MissingField(_)
                | MappingError::Overlap { .. }
                | MappingError::BeyondCluster { .. } => {}
            }
        }
    }
}

/// Planner on degenerate clusters (1 device, prime-sized) still
/// produces sane answers.
#[test]
fn planner_handles_degenerate_clusters() {
    use hyperparallel::config::ModelDesc;
    use hyperparallel::hypershard::{plan, PlannerConfig};
    let cfg = PlannerConfig {
        allow_offload: true,
        ..Default::default()
    };
    // 1-device "cluster"
    let one = Topology::new(
        Geometry { racks: 1, boards_per_rack: 1, dies_per_board: 1 },
        Fabric::supernode(),
        DeviceSpec::ascend_910c(),
    );
    let plans = plan(&ModelDesc::tiny_moe(), &one, &cfg);
    assert_eq!(plans.len(), 1);
    assert_eq!(plans[0].strategy.device_count(), 1);
    // 7-device board (prime): only dp7 and tp7 factorizations exist
    let prime = Topology::new(
        Geometry { racks: 1, boards_per_rack: 1, dies_per_board: 7 },
        Fabric::supernode(),
        DeviceSpec::ascend_910c(),
    );
    for c in plan(&ModelDesc::tiny_moe(), &prime, &cfg) {
        assert_eq!(c.strategy.device_count(), 7);
    }
}
