//! Bit-exact report snapshots of the checked-in PR 2–7 scenarios.
//!
//! Writes every `summary_kv()` value of the seed scenarios as raw
//! f64 bit patterns to the path in `HP_REPORT_BITS` (skipped when the
//! variable is unset). Used to prove refactors of the trace path keep
//! indexed-sink reports bit-identical: dump before, dump after, diff.

use std::fmt::Write as _;

use hyperparallel::hypermpmd::coschedule::{
    cosched_scenario, fault_cosched_scenario, run_cosched, CoschedMode,
};
use hyperparallel::serving::cluster::{
    agentic_scenario, autoscale_crash_scenario, autoscale_scenario, crossover_scenario,
    run_agentic_scenario, run_cluster_scenario, ClusterFabric, ClusterMode,
};
use hyperparallel::serving::metrics::{run_scenario, smoke_scenario};

fn dump(out: &mut String, name: &str, kv: &[(String, f64)]) {
    for (k, v) in kv {
        writeln!(out, "{name}.{k} = {:#018x}", v.to_bits()).unwrap();
    }
}

#[test]
fn report_bits_snapshot() {
    let path = match std::env::var("HP_REPORT_BITS") {
        Ok(p) if !p.is_empty() => p,
        _ => return, // snapshot dump is opt-in
    };
    let mut out = String::new();

    let rep = run_scenario(&smoke_scenario(20.0, 0.2, 4));
    dump(&mut out, "smoke", &rep.summary_kv());

    for (label, fabric, mode) in [
        ("xover.sn.disagg", ClusterFabric::Supernode, ClusterMode::Disaggregated),
        ("xover.legacy.coloc", ClusterFabric::Legacy, ClusterMode::Colocated),
    ] {
        let rep = run_cluster_scenario(&crossover_scenario(fabric, mode));
        dump(&mut out, label, &rep.summary_kv());
    }

    let rep = run_cluster_scenario(&autoscale_scenario(ClusterFabric::Supernode, true));
    dump(&mut out, "autoscale.elastic", &rep.summary_kv());
    let rep = run_cluster_scenario(&autoscale_crash_scenario(ClusterFabric::Supernode));
    dump(&mut out, "autoscale.crash", &rep.summary_kv());

    let rep = run_cosched(&cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched));
    dump(&mut out, "cosched.serving", &rep.serving.summary_kv());
    dump(&mut out, "cosched.train", &rep.train.summary_kv());

    let rep = run_cosched(&fault_cosched_scenario());
    dump(&mut out, "faultco.serving", &rep.serving.summary_kv());
    dump(&mut out, "faultco.train", &rep.train.summary_kv());

    let rep = run_agentic_scenario(&agentic_scenario(ClusterFabric::Supernode, true));
    dump(&mut out, "agentic.aware", &rep.summary_kv());

    std::fs::write(&path, out).expect("write report bits");
}
