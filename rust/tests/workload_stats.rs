//! Statistical validation of the workload generators (ISSUE 4
//! satellite): empirical arrival rates within 5% of `mean_rate()`
//! over long horizons, `LengthDist::sample` means matching
//! `LengthDist::mean()`, and `with_mean_rate` round-trips.
//!
//! Everything runs on fixed seeds, so these are deterministic
//! regressions, not flaky statistics — the committed bounds were
//! verified against the exact same RNG sequence through the Python
//! mirror (`tools/cluster_simcheck.py`'s `Rng` port), and the chosen
//! horizons put the estimators' standard error several times below
//! the 5% gate.

use hyperparallel::serving::{diurnal_two_tenant, ArrivalProcess, LengthDist, WorkloadConfig};
use hyperparallel::util::rng::Rng;

fn empirical_rate(arrival: ArrivalProcess, horizon: f64, seed: u64) -> f64 {
    let cfg = WorkloadConfig {
        arrival,
        prompt: LengthDist::Fixed(8),
        output: LengthDist::Fixed(8),
        seed,
    };
    cfg.generate(horizon).len() as f64 / horizon
}

fn rel_err(measured: f64, expected: f64) -> f64 {
    (measured / expected - 1.0).abs()
}

#[test]
fn poisson_empirical_rate_within_5pct_of_mean_rate() {
    let arr = ArrivalProcess::Poisson { rate: 40.0 };
    assert_eq!(arr.mean_rate(), 40.0);
    // 16k arrivals: standard error ~0.8%, measured 0.35%
    let emp = empirical_rate(arr, 400.0, 7);
    assert!(
        rel_err(emp, 40.0) <= 0.05,
        "poisson empirical rate {emp} vs 40"
    );
}

#[test]
fn bursty_empirical_rate_within_5pct_of_mean_rate() {
    let arr = ArrivalProcess::Bursty {
        rate_on: 60.0,
        rate_off: 6.0,
        mean_on: 2.0,
        mean_off: 6.0,
    };
    // time-weighted analytic mean: (60·2 + 6·6) / 8
    let mean = arr.mean_rate();
    assert!((mean - 19.5).abs() < 1e-12, "analytic mean {mean}");
    // the estimator's variance is dominated by the on/off cycle count,
    // so the horizon spans ~1000 cycles; measured error 0.23%
    let emp = empirical_rate(arr, 8000.0, 5);
    assert!(
        rel_err(emp, mean) <= 0.05,
        "bursty empirical rate {emp} vs {mean}"
    );
}

#[test]
fn diurnal_empirical_rate_within_5pct_of_mean_rate() {
    let arr = diurnal_two_tenant(24.0, 48.0);
    let mean = arr.mean_rate();
    assert!(
        (mean - 24.0).abs() < 1e-9,
        "tenant base rates must sum to the requested mean: {mean}"
    );
    // 20 full day-periods of Lewis thinning; measured error 1.2%
    let emp = empirical_rate(arr.clone(), 960.0, 13);
    assert!(
        rel_err(emp, mean) <= 0.05,
        "diurnal empirical rate {emp} vs {mean}"
    );
    // the modulation itself: the preset swings ≥4x, a flat process 1x
    assert!(arr.swing_ratio(48.0, 4800) >= 4.0);
    assert!((ArrivalProcess::Poisson { rate: 3.0 }.swing_ratio(10.0, 100) - 1.0).abs() < 1e-12);
}

#[test]
fn length_dist_sample_means_match_mean() {
    let n = 50_000usize;
    // uniform: mean() is the midpoint; measured sample error 0.11%
    let u = LengthDist::Uniform { lo: 10, hi: 50 };
    assert_eq!(u.mean(), 30.0);
    let mut rng = Rng::new(17);
    let m: f64 = (0..n).map(|_| u.sample(&mut rng) as f64).sum::<f64>() / n as f64;
    assert!((m - 30.0).abs() <= 0.6, "uniform sample mean {m}");

    // log-normal with a cap far in the tail: sample mean matches the
    // uncapped analytic exp(mu + sigma²/2); measured error 0.32%
    let ln = LengthDist::LogNormal {
        mu: 5.0,
        sigma: 0.4,
        cap: 100_000,
    };
    let expect = (5.0f64 + 0.4f64 * 0.4 / 2.0).exp();
    assert!((ln.mean() - expect).abs() < 1e-9);
    let mut rng = Rng::new(19);
    let m: f64 = (0..n).map(|_| ln.sample(&mut rng) as f64).sum::<f64>() / n as f64;
    assert!(
        rel_err(m, expect) <= 0.05,
        "lognormal sample mean {m} vs {expect}"
    );

    // fixed: every sample is the constant, mean is exact
    let f = LengthDist::Fixed(37);
    assert_eq!(f.mean(), 37.0);
    let mut rng = Rng::new(23);
    assert!((0..1000).all(|_| f.sample(&mut rng) == 37));
}

#[test]
fn with_mean_rate_round_trips() {
    let procs = [
        ArrivalProcess::Poisson { rate: 12.0 },
        ArrivalProcess::Bursty {
            rate_on: 60.0,
            rate_off: 6.0,
            mean_on: 2.0,
            mean_off: 6.0,
        },
        diurnal_two_tenant(24.0, 48.0),
    ];
    for p in &procs {
        // rescaling to any target lands exactly on that mean
        for target in [1.0, 17.5, 240.0] {
            let scaled = p.with_mean_rate(target);
            assert!(
                (scaled.mean_rate() - target).abs() <= 1e-9 * target.max(1.0),
                "{p:?} -> {target}: got {}",
                scaled.mean_rate()
            );
        }
        // rescaling to the current mean is the identity (k = 1.0)
        assert_eq!(&p.with_mean_rate(p.mean_rate()), p);
        // relative shape is preserved: doubling the mean doubles the
        // instantaneous swing envelope but not its ratio
        let doubled = p.with_mean_rate(2.0 * p.mean_rate());
        assert!(
            (doubled.swing_ratio(48.0, 480) - p.swing_ratio(48.0, 480)).abs() < 1e-9,
            "rescaling must not distort the diurnal shape"
        );
    }
    // a zero-rate process cannot be rescaled and stays itself
    let zero = ArrivalProcess::Poisson { rate: 0.0 };
    assert_eq!(zero.with_mean_rate(5.0), zero);
}
