//! Integration: the full PJRT path — artifacts → runtime → executor →
//! data-parallel trainer. Requires `make artifacts` (skips otherwise).

use hyperparallel::runtime::Runtime;
use hyperparallel::trainer::{train, Corpus, TrainOptions};

fn runtime() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    if !std::path::Path::new("artifacts/meta.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu("artifacts").expect("pjrt cpu client"))
}

#[test]
fn manifest_matches_tiny_moe_descriptor() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    assert_eq!(m.vocab, 512);
    assert_eq!(m.seq, 128);
    assert_eq!(m.batch, 8);
    assert_eq!(m.meta["experts"], 8);
    assert_eq!(m.meta["layers"], 4);
    // params + momenta, same count
    assert_eq!(m.params.len() % 2, 0);
    let n = m.params.len() / 2;
    for i in 0..n {
        assert_eq!(m.params[n + i].name, format!("mom.{}", m.params[i].name));
        assert_eq!(m.params[n + i].shape, m.params[i].shape);
    }
}

#[test]
fn kernel_demo_executes() {
    let Some(mut rt) = runtime() else { return };
    rt.load("kernel_demo").unwrap();
    use hyperparallel::runtime::{literal_f32, literal_i32, to_f32};
    let x = vec![0.5f32; 64 * 32];
    let w1 = vec![0.01f32; 4 * 32 * 64];
    let w2 = vec![0.01f32; 4 * 64 * 32];
    let assign = vec![0i32; 64];
    let out = rt
        .execute(
            "kernel_demo",
            &[
                literal_f32(&[64, 32], &x).unwrap(),
                literal_f32(&[4, 32, 64], &w1).unwrap(),
                literal_f32(&[4, 64, 32], &w2).unwrap(),
                literal_i32(&[64], &assign).unwrap(),
            ],
        )
        .unwrap();
    let y = to_f32(&out[0]).unwrap();
    assert_eq!(y.len(), 64 * 32);
    // all tokens identical + same expert => identical rows
    for row in y.chunks(32).skip(1) {
        assert_eq!(row, &y[..32]);
    }
    // gelu(0.5*0.01*32)=gelu(0.16)... output = 64*... just check finite non-zero
    assert!(y[0].is_finite() && y[0] != 0.0);
}

#[test]
fn train_two_steps_reduces_loss_generally() {
    let Some(mut rt) = runtime() else { return };
    rt.load("train_step").unwrap();
    let report = train(
        &rt,
        &TrainOptions {
            steps: 3,
            seed: 123,
            dp: 1,
            log_every: 1,
        },
    )
    .unwrap();
    assert!(report.final_loss.is_finite());
    assert!(
        report.final_loss < report.first_loss,
        "loss {} -> {}",
        report.first_loss,
        report.final_loss
    );
}

#[test]
fn data_parallel_two_ways_stays_in_sync_and_learns() {
    let Some(mut rt) = runtime() else { return };
    rt.load("train_step").unwrap();
    let manifest = rt.manifest().unwrap();
    let mut dp = hyperparallel::runtime::DataParallelTrainer::new(manifest.clone(), 2, 9);
    let mut corpus = Corpus::new(manifest.vocab, 9);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..2 {
        let shards = corpus.dp_shards(manifest.batch * 2, manifest.seq, 2);
        last = dp.step(&rt, &shards).unwrap();
        first.get_or_insert(last);
    }
    assert!(dp.in_sync(), "replicas diverged after all-reduce");
    assert!(last < first.unwrap());
}

#[test]
fn forward_artifact_produces_logits() {
    let Some(mut rt) = runtime() else { return };
    rt.load("forward").unwrap();
    let manifest = rt.manifest().unwrap();
    // forward takes only the true params (not momenta)
    let n = manifest.params.len() / 2;
    let mut m2 = manifest.clone();
    m2.params.truncate(n);
    let exec = hyperparallel::runtime::TrainExecutor::new(m2, 5);
    let mut corpus = Corpus::new(manifest.vocab, 5);
    let (tokens, _) = corpus.batch(manifest.batch, manifest.seq);
    let logits = exec.forward(&rt, &tokens).unwrap();
    assert_eq!(
        logits.len(),
        manifest.batch * manifest.seq * manifest.vocab
    );
    assert!(logits.iter().all(|x| x.is_finite()));
}
