//! Determinism regression (ISSUE 4 satellite, extended by ISSUEs 5,
//! 6, 9, and 10): `cluster_rate_sweep` over the crossover scenario AND
//! the elastic-autoscale scenario AND `cosched_rate_sweep` over the
//! co-scheduled scenario — fault-free, with the ISSUE 6 fault plan
//! (link degrades, device fails, retry/hedge machinery) injected, and
//! on the ISSUE 9 heterogeneous mixed-generation fleet — produce
//! bit-identical reports whether the sweep runs sequentially
//! (`HP_SWEEP_THREADS=1`) or fanned across 8 workers.
//!
//! Like `sweep_env.rs`, this binary holds exactly one test: the
//! assertions mutate a process-global environment variable, and
//! concurrent setenv/getenv from parallel tests is undefined behavior
//! in glibc — an isolated binary is the only safe home.

use hyperparallel::hypermpmd::coschedule::{
    cosched_rate_sweep, cosched_scenario, cosched_train_job, fault_cosched_scenario,
    fleet_cosched_scenario, CoschedMode, FleetScenario,
};
use hyperparallel::hypershard::{autotune, AutoTuneConfig, ElasticObjective};
use hyperparallel::serving::{
    autoscale_scenario, autoscale_slo, cluster_rate_sweep, cluster_slo, crossover_scenario,
    ClusterFabric, ClusterMode, ClusterScenario, OperatingPoint, Slo, CLUSTER_RATES,
};

fn assert_bit_identical(label: &str, a: &[OperatingPoint], b: &[OperatingPoint]) {
    assert_eq!(a.len(), b.len(), "{label}: row count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let row = format!("{label} row {i}");
        assert_eq!(x.rate.to_bits(), y.rate.to_bits(), "{row}: rate");
        assert_eq!(x.completed, y.completed, "{row}: completed");
        assert_eq!(x.rejected, y.rejected, "{row}: rejected");
        assert_eq!(x.preemptions, y.preemptions, "{row}: preemptions");
        assert_eq!(x.demotions, y.demotions, "{row}: demotions");
        assert_eq!(
            x.peak_context_tokens, y.peak_context_tokens,
            "{row}: peak context"
        );
        assert_eq!(x.attains_slo, y.attains_slo, "{row}: attains");
        assert_eq!(
            x.admitted_qps.to_bits(),
            y.admitted_qps.to_bits(),
            "{row}: qps"
        );
        assert_eq!(x.goodput.to_bits(), y.goodput.to_bits(), "{row}: goodput");
        assert_eq!(x.p50_ttft.to_bits(), y.p50_ttft.to_bits(), "{row}: p50 ttft");
        assert_eq!(x.p99_ttft.to_bits(), y.p99_ttft.to_bits(), "{row}: p99 ttft");
        assert_eq!(x.p99_tpot.to_bits(), y.p99_tpot.to_bits(), "{row}: p99 tpot");
        assert_eq!(
            x.mean_utilization.to_bits(),
            y.mean_utilization.to_bits(),
            "{row}: utilization"
        );
    }
}

fn both_thread_counts(label: &str, sc: &ClusterScenario, rates: &[f64], slo: &Slo) {
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let sequential = cluster_rate_sweep(sc, rates, slo);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let parallel = cluster_rate_sweep(sc, rates, slo);
    assert_bit_identical(label, &sequential, &parallel);
}

#[test]
fn cluster_sweeps_bit_identical_across_worker_counts() {
    // the PR 3 crossover path (static disaggregated cluster)...
    both_thread_counts(
        "crossover disagg/supernode",
        &crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated),
        &CLUSTER_RATES[..4],
        &cluster_slo(),
    );
    // ...and the elastic path: warm-ups, drains, and limbo handling
    // must replay identically no matter how the sweep is scheduled
    both_thread_counts(
        "autoscale elastic/supernode",
        &autoscale_scenario(ClusterFabric::Supernode, true),
        &[18.0, 24.0],
        &autoscale_slo(),
    );
    // ...and the ISSUE 5 co-scheduled path: broker mediation, trainer
    // preemption/resharding, and the serving events must interleave
    // identically regardless of sweep parallelism
    let cosched = cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched);
    let slo = autoscale_slo();
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let seq = cosched_rate_sweep(&cosched, &[18.0, 24.0], &slo);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let par = cosched_rate_sweep(&cosched, &[18.0, 24.0], &slo);
    let (seq_ops, seq_steps): (Vec<OperatingPoint>, Vec<u64>) = seq.into_iter().unzip();
    let (par_ops, par_steps): (Vec<OperatingPoint>, Vec<u64>) = par.into_iter().unzip();
    assert_bit_identical("cosched supernode", &seq_ops, &par_ops);
    assert_eq!(seq_steps, par_steps, "cosched: training step counts");
    // ...and the ISSUE 6 fault-injected path: retry parks, hedged
    // re-routes, device-fail aborts and checkpoint-restores must all
    // land on the same virtual-clock instants regardless of sweep
    // parallelism
    let faulted = fault_cosched_scenario();
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let fseq = cosched_rate_sweep(&faulted, &[18.0, 24.0], &slo);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let fpar = cosched_rate_sweep(&faulted, &[18.0, 24.0], &slo);
    let (fseq_ops, fseq_steps): (Vec<OperatingPoint>, Vec<u64>) = fseq.into_iter().unzip();
    let (fpar_ops, fpar_steps): (Vec<OperatingPoint>, Vec<u64>) = fpar.into_iter().unzip();
    assert_bit_identical("cosched faulted", &fseq_ops, &fpar_ops);
    assert_eq!(fseq_steps, fpar_steps, "faulted cosched: training step counts");
    // ...and the ISSUE 9 heterogeneous-fleet path: compute-weighted
    // step planning, pool-aware harvesting, the crossing rule, and
    // DCN-priced reshards must replay identically across sweep worker
    // counts
    let fleet = fleet_cosched_scenario(FleetScenario::MixedGenerations, true);
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let hseq = cosched_rate_sweep(&fleet, &[18.0, 24.0], &slo);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let hpar = cosched_rate_sweep(&fleet, &[18.0, 24.0], &slo);
    let (hseq_ops, hseq_steps): (Vec<OperatingPoint>, Vec<u64>) = hseq.into_iter().unzip();
    let (hpar_ops, hpar_steps): (Vec<OperatingPoint>, Vec<u64>) = hpar.into_iter().unzip();
    assert_bit_identical("cosched fleet", &hseq_ops, &hpar_ops);
    assert_eq!(hseq_steps, hpar_steps, "fleet cosched: training step counts");
    // one streaming-sink row of the same fleet cell: the sink choice
    // and the fleet pricing compose — determinism across worker
    // counts, and the streaming row matches the indexed row bitwise
    let mut fleet_stream = fleet.clone();
    fleet_stream.cluster.trace_mode = hyperparallel::sim::TraceMode::Streaming;
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let fs_seq = cosched_rate_sweep(&fleet_stream, &[18.0], &slo);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let fs_par = cosched_rate_sweep(&fleet_stream, &[18.0], &slo);
    let (fs_seq_ops, fs_seq_steps): (Vec<OperatingPoint>, Vec<u64>) = fs_seq.into_iter().unzip();
    let (fs_par_ops, fs_par_steps): (Vec<OperatingPoint>, Vec<u64>) = fs_par.into_iter().unzip();
    assert_bit_identical("cosched fleet streaming-sink", &fs_seq_ops, &fs_par_ops);
    assert_eq!(fs_seq_steps, fs_par_steps, "fleet streaming: step counts");
    assert_bit_identical(
        "fleet streaming vs indexed sink",
        &hseq_ops[..1],
        &fs_seq_ops,
    );
    assert_eq!(hseq_steps[..1], fs_seq_steps[..], "fleet sinks: steps");
    // ...and the ISSUE 8 streaming-sink path: the same crossover sweep
    // with the incremental accumulators instead of the interval log —
    // the sink choice must not perturb the sweep's determinism, and
    // the streaming rows must match the indexed rows bitwise too
    let mut streaming = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
    streaming.cluster.trace_mode = hyperparallel::sim::TraceMode::Streaming;
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let sseq = cluster_rate_sweep(&streaming, &CLUSTER_RATES[..4], &cluster_slo());
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let spar = cluster_rate_sweep(&streaming, &CLUSTER_RATES[..4], &cluster_slo());
    assert_bit_identical("crossover streaming-sink", &sseq, &spar);
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let indexed = cluster_rate_sweep(
        &crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated),
        &CLUSTER_RATES[..4],
        &cluster_slo(),
    );
    assert_bit_identical("streaming vs indexed sink", &indexed, &sseq);
    // ...and the ISSUE 10 auto-tuner: the generate → prune → simulate
    // → refine loop fans its predict and simulate waves through the
    // same sweep workers, so its ranked report must come back
    // bit-identical across worker counts too
    let fleet = hyperparallel::supernode::Fleet::mixed_generations();
    let obj = ElasticObjective::new(cosched_train_job(), fleet, true);
    let tune_cfg = AutoTuneConfig::default();
    std::env::set_var("HP_SWEEP_THREADS", "1");
    let tseq = autotune(&obj, &tune_cfg);
    std::env::set_var("HP_SWEEP_THREADS", "8");
    let tpar = autotune(&obj, &tune_cfg);
    assert_eq!(tseq.ranked.len(), tpar.ranked.len(), "autotune: ranked rows");
    for (i, (a, b)) in tseq.ranked.iter().zip(&tpar.ranked).enumerate() {
        let row = format!("autotune row {i}");
        assert_eq!(a.label, b.label, "{row}: label");
        assert_eq!(a.predicted.to_bits(), b.predicted.to_bits(), "{row}: predicted");
        assert_eq!(a.simulated.to_bits(), b.simulated.to_bits(), "{row}: simulated");
    }
    assert_eq!(tseq.generated, tpar.generated, "autotune: generated");
    assert_eq!(tseq.infeasible, tpar.infeasible, "autotune: infeasible");
    assert_eq!(tseq.pruned, tpar.pruned, "autotune: pruned");
    assert_eq!(tseq.simulated, tpar.simulated, "autotune: simulated");
    std::env::remove_var("HP_SWEEP_THREADS");
}
