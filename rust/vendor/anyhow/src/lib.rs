//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build container has no registry access, so this path crate
//! provides the slice of `anyhow`'s API the framework actually uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros,
//! and the [`Context`] extension trait. Error values are rendered
//! strings with a context chain — enough for CLI diagnostics, without
//! backtraces or downcasting.

use std::fmt;

/// A rendered error: the root cause plus any context frames added via
/// [`Context`]. Frame 0 is the outermost (most recently added) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Push an outer context frame (what [`Context`] does).
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The outermost message.
    pub fn root(&self) -> &str {
        &self.chain[0]
    }

    /// Context frames, outermost first.
    pub fn frames(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, outermost first, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// `?` conversion from any std error. `Error` deliberately does NOT
// implement `std::error::Error` (same as real anyhow) so this blanket
// impl cannot conflict with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible values.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("root cause {}", 42))
    }

    #[test]
    fn chain_renders_outermost_first() {
        let e = fails().with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause 42");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn ensure_formats() {
        fn check(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert_eq!(format!("{}", check(11).unwrap_err()), "x too big: 11");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
    }
}
