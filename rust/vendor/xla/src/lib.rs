//! Offline compile-time stub of the `xla` crate.
//!
//! Mirrors the slice of xla-rs that `runtime::pjrt` touches:
//! [`PjRtClient`], [`PjRtLoadedExecutable`], [`PjRtBuffer`],
//! [`Literal`], [`HloModuleProto`], and [`XlaComputation`]. Literals
//! are real host arrays (so `Literal::vec1 → reshape → to_vec`
//! round-trips work and the runtime's marshalling tests pass under
//! `--features pjrt`); everything that would need a real PJRT client
//! errors with a clear "unavailable offline" message. Types that can
//! only be produced *by* a client carry an uninhabited field, so their
//! methods are statically unreachable — the stub cannot silently
//! pretend to execute.

use std::fmt;

/// Rendered error, formatted like xla-rs errors are consumed (`{e:?}`).
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: xla stub (offline build) — wire the real `xla` crate to execute artifacts"
        ))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Statically uninhabited: values of client-produced types cannot
/// exist in the stub, making their methods unreachable by construction.
#[derive(Debug, Clone, Copy)]
enum Never {}

/// Typed literal payload.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types the stub marshals (mirrors xla-rs native types).
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::F32(data)
    }

    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            LiteralData::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::I32(data)
    }

    fn unwrap(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            LiteralData::F32(_) => None,
        }
    }
}

/// Host literal: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            shape: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    pub fn elements(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    /// Reinterpret under a new shape with the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if dims.iter().any(|&d| d < 0) || n as usize != self.elements() {
            return Err(Error(format!(
                "reshape: {dims:?} does not hold {} elements",
                self.elements()
            )));
        }
        Ok(Literal {
            shape: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Extract typed host data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Flatten a tuple literal. Tuple literals only come out of
    /// execution, which the stub cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module — only producible by parsing, which needs xla.
pub struct HloModuleProto(Never);

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<std::path::Path>) -> Result<Self, Error> {
        Err(Error(format!(
            "HloModuleProto::from_text_file({}): xla stub (offline build)",
            path.as_ref().display()
        )))
    }
}

/// Computation wrapper.
pub struct XlaComputation(Never);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

/// Device buffer — only producible by a client.
pub struct PjRtBuffer(Never);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.0 {}
    }
}

/// Compiled executable — only producible by a client.
pub struct PjRtLoadedExecutable(Never);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.0 {}
    }

    pub fn execute_b<B>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.0 {}
    }
}

/// PJRT client. `cpu()` always fails offline, so every downstream
/// method is unreachable.
pub struct PjRtClient(Never);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match self.0 {}
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        match self.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let lit = Literal::vec1(&data).reshape(&[3, 4]).unwrap();
        assert_eq!(lit.shape(), &[3, 4]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_rejects_wrong_count() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn client_is_unavailable_offline() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("offline"));
    }

    #[test]
    fn hlo_parse_is_unavailable_offline() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
