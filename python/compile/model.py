"""L2: the JAX MoE-transformer forward/backward/train-step.

Mirrors the Rust `ModelDesc::tiny_moe()` descriptor: a 4-layer pre-norm
transformer with top-2 MoE FFN layers, small enough to train end to end
on the CPU PJRT client while exercising the full three-layer stack
(Pallas kernels -> JAX graph -> HLO artifact -> Rust runtime).

The Pallas kernels carry custom VJPs whose backward is the vjp of the
pure-jnp reference (`kernels/ref.py`): numerically identical (pytest
asserts kernel == ref) and robust to AD limitations of interpret-mode
pallas_call internals (fori_loop online softmax is not transposable).

The optimizer is SGD with momentum — *linear in the gradient*, so
averaging (params, momentum) across data-parallel replicas is exactly
gradient averaging; the Rust `DataParallelTrainer` relies on this.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import attention as attn_k
from compile.kernels import moe_ffn as moe_k
from compile.kernels import ref


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    ffn_mult: int = 4
    experts: int = 8
    top_k: int = 2
    seq: int = 128
    batch: int = 8
    lr: float = 0.03
    momentum: float = 0.9

    @property
    def head_dim(self):
        return self.hidden // self.heads

    @property
    def ffn(self):
        return self.hidden * self.ffn_mult


# --- parameter schema (explicit order = artifact argument order) -------

def param_specs(cfg: ModelConfig):
    """[(name, shape, init_std)] in the exact artifact argument order."""
    h, f, e, l, v = cfg.hidden, cfg.ffn, cfg.experts, cfg.layers, cfg.vocab
    std = 0.02
    return [
        ("embed", (v, h), std),
        ("qkv", (l, h, 3 * h), std),
        ("attn_out", (l, h, h), std),
        ("norm1", (l, h), 0.0),  # init 1 added at use: stored as delta
        ("norm2", (l, h), 0.0),
        ("gate", (l, h, e), std),
        ("w1", (l, e, h, f), std),
        ("w2", (l, e, f, h), std),
        ("final_norm", (h,), 0.0),
    ]


def init_params(cfg: ModelConfig, key):
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    return [
        jax.random.normal(k, shape, jnp.float32) * std
        for k, (_, shape, std) in zip(keys, specs)
    ]


# --- kernel ops with reference-backward custom VJPs ---------------------

# capacity factor 2: each expert's bucket holds 2x the mean load;
# overflow tokens are dropped for that expert (Switch-style).
CAPACITY_FACTOR = 2.0


def _capacity(t, e):
    return max(int(CAPACITY_FACTOR * t / e), 16)


@jax.custom_vjp
def moe_ffn_op(x, w1, w2, assign):
    cap = _capacity(x.shape[0], w1.shape[0])
    return moe_k.moe_ffn(x, w1, w2, assign, capacity=cap)


def _moe_fwd(x, w1, w2, assign):
    cap = _capacity(x.shape[0], w1.shape[0])
    return moe_k.moe_ffn(x, w1, w2, assign, capacity=cap), (x, w1, w2, assign)


def _moe_bwd(res, g):
    x, w1, w2, assign = res
    cap = _capacity(x.shape[0], w1.shape[0])
    # backward through the dense-bucketed twin (bitwise-equivalent
    # computation, efficient einsum gradients)
    _, vjp = jax.vjp(
        lambda x_, w1_, w2_: moe_k.moe_ffn_dense(x_, w1_, w2_, assign, capacity=cap),
        x,
        w1,
        w2,
    )
    dx, dw1, dw2 = vjp(g)
    zero = np.zeros(assign.shape, dtype=jax.dtypes.float0)
    return dx, dw1, dw2, zero


moe_ffn_op.defvjp(_moe_fwd, _moe_bwd)


@jax.custom_vjp
def attention_op(q, k, v):
    return attn_k.flash_attention(q, k, v, causal=True)


def _attn_fwd(q, k, v):
    return attn_k.flash_attention(q, k, v, causal=True), (q, k, v)


def _attn_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=True), q, k, v)
    return vjp(g)


attention_op.defvjp(_attn_fwd, _attn_bwd)


# --- forward -------------------------------------------------------------

def rmsnorm(x, gamma_delta):
    return ref.rmsnorm_ref(x, 1.0 + gamma_delta)


def topk_manual(logits, k):
    """Iterated argmax top-k.

    `jax.lax.top_k` lowers to an HLO `topk(..., largest=true)` op that
    the xla_extension 0.5.1 text parser rejects; argmax + masking lowers
    to plain reduce/select ops that round-trip cleanly.
    """
    vals, idxs = [], []
    x = logits
    e = logits.shape[-1]
    for _ in range(k):
        i = jnp.argmax(x, axis=-1)
        v = jnp.max(x, axis=-1)
        idxs.append(i)
        vals.append(v)
        mask = jax.nn.one_hot(i, e, dtype=bool)
        x = jnp.where(mask, -jnp.inf, x)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def block(cfg: ModelConfig, params, li, x):
    """One transformer block: attn + top-k MoE FFN, pre-norm residual."""
    _, qkv, attn_out, norm1, norm2, gate, w1, w2, _ = params
    b, s, h = x.shape
    hd, d = cfg.heads, cfg.head_dim

    # attention
    xn = rmsnorm(x, norm1[li])
    proj = xn @ qkv[li]  # [B, S, 3H]
    q, k, v = jnp.split(proj, 3, axis=-1)
    q = q.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
    o = attention_op(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h)
    x = x + o @ attn_out[li]

    # MoE FFN (top-k routing, softmax combine over chosen experts)
    xn = rmsnorm(x, norm2[li]).reshape(b * s, h)
    logits = xn @ gate[li]  # [T, E]
    topv, topi = topk_manual(logits, cfg.top_k)
    weights = jax.nn.softmax(topv, axis=-1)  # [T, K]
    out = jnp.zeros_like(xn)
    for kk in range(cfg.top_k):
        assign = jax.lax.stop_gradient(topi[:, kk])
        yk = moe_ffn_op(xn, w1[li], w2[li], assign)
        out = out + yk * weights[:, kk : kk + 1]
    return x + out.reshape(b, s, h)


def forward(cfg: ModelConfig, params, tokens):
    """tokens [B, S] int32 -> logits [B, S, V]."""
    embed, *_, final_norm = params[0], params[-1]
    embed = params[0]
    x = embed[tokens]  # [B, S, H]
    for li in range(cfg.layers):
        x = block(cfg, params, li, x)
    x = rmsnorm(x, params[-1])
    return x @ embed.T  # tied lm head


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# --- train step (flat signature for the artifact) ------------------------

def make_train_step(cfg: ModelConfig):
    """Returns f(*params, *momenta, tokens, targets) ->
    (*new_params, *new_momenta, loss) with SGD+momentum."""
    n = len(param_specs(cfg))

    def train_step(*args):
        params = list(args[:n])
        moms = list(args[n : 2 * n])
        tokens, targets = args[2 * n], args[2 * n + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, tokens, targets)
        )(params)
        new_params, new_moms = [], []
        for p, m, g in zip(params, moms, grads):
            m_new = cfg.momentum * m + g
            new_moms.append(m_new)
            new_params.append(p - cfg.lr * m_new)
        return tuple(new_params) + tuple(new_moms) + (loss.reshape(1),)

    return train_step


def make_forward(cfg: ModelConfig):
    """Returns f(*params, tokens) -> (logits,) for the inference artifact."""
    n = len(param_specs(cfg))

    def fwd(*args):
        params = list(args[:n])
        tokens = args[n]
        return (forward(cfg, params, tokens),)

    return fwd


# --- pure-reference model (oracle for python tests) ----------------------

def forward_ref(cfg: ModelConfig, params, tokens):
    """Same model with reference (non-pallas) kernels throughout."""
    embed = params[0]
    _, qkv, attn_out, norm1, norm2, gate, w1, w2, final_norm = params
    x = embed[tokens]
    b, s, h = x.shape
    hd, d = cfg.heads, cfg.head_dim
    for li in range(cfg.layers):
        xn = rmsnorm(x, norm1[li])
        proj = xn @ qkv[li]
        q, k, v = jnp.split(proj, 3, axis=-1)
        q = q.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, hd, d).transpose(0, 2, 1, 3)
        o = ref.attention_ref(q, k, v, causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, h)
        x = x + o @ attn_out[li]
        xn = rmsnorm(x, norm2[li]).reshape(b * s, h)
        logits = xn @ gate[li]
        topv, topi = topk_manual(logits, cfg.top_k)
        weights = jax.nn.softmax(topv, axis=-1)
        out = jnp.zeros_like(xn)
        for kk in range(cfg.top_k):
            assign = topi[:, kk]
            yk = ref.moe_ffn_ref(xn, w1[li], w2[li], assign)
            out = out + yk * weights[:, kk : kk + 1]
        x = x + out.reshape(b, s, h)
    x = rmsnorm(x, final_norm)
    return x @ embed.T
