"""Pallas kernel: capacity-bucketed MoE expert FFN (the L1 hot spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's MoE
FFN runs on Ascend's AICube systolic engine fed from explicit local
buffers. On TPU-style Pallas that maps to:

  * grid over (expert, token-block): each program instance computes one
    expert's FFN over one block of its capacity bucket — a regular
    dense GEMM the MXU can saturate;
  * BlockSpecs stage x/w1/w2 HBM->VMEM per block, the analogue of the
    Ascend L1/UB staging the paper's kernels do with DMA descriptors;
  * the gather (token->bucket) and scatter (bucket->token) are cheap
    vector-path ops done *outside* the kernel so the kernel stays a
    clean matmul pipeline.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU perf is estimated from VMEM footprint + MXU
utilization in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_ffn_kernel(x_ref, w1_ref, w2_ref, o_ref):
    """One (expert, token-block): o = gelu(x @ w1) @ w2.

    x_ref:  [Tb, H]   one token block of this expert's bucket (VMEM)
    w1_ref: [H, F]    this expert's up-projection (VMEM)
    w2_ref: [F, H]    this expert's down-projection (VMEM)
    o_ref:  [Tb, H]
    """
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jax.nn.gelu(h)
    o_ref[...] = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_t",))
def moe_ffn_bucketed(xb, w1, w2, block_t=64):
    """Expert FFN over capacity buckets.

    Args:
      xb: [E, C, H]  bucketed tokens (expert-major, capacity C)
      w1: [E, H, F]
      w2: [E, F, H]
      block_t: token-block size per program instance.

    Returns [E, C, H].
    """
    e, c, h = xb.shape
    f = w1.shape[-1]
    assert c % block_t == 0, f"capacity {c} must divide block_t {block_t}"
    grid = (e, c // block_t)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((None, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((None, f, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_t, h), lambda ei, ti: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), xb.dtype),
        interpret=True,
    )(xb, w1, w2)


def bucket_by_expert(x, assign, num_experts, capacity):
    """Scatter tokens into per-expert capacity buckets.

    Tokens beyond an expert's capacity are dropped (standard Switch-
    style capacity truncation); the inverse scatter restores order and
    zero-fills dropped tokens.

    Returns (buckets [E, C, H], slot [T] int32 position-in-bucket or -1).
    """
    t = x.shape[0]
    # position of each token within its expert's arrival order
    onehot = jax.nn.one_hot(assign, num_experts, dtype=jnp.int32)  # [T, E]
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot  # [T, E]
    pos = jnp.take_along_axis(pos_in_expert, assign[:, None], axis=1)[:, 0]
    keep = pos < capacity
    slot = jnp.where(keep, pos, -1)
    buckets = jnp.zeros((num_experts, capacity) + x.shape[1:], x.dtype)
    buckets = buckets.at[assign, jnp.where(keep, slot, 0)].add(
        jnp.where(keep[:, None], x, 0.0)
    )
    del t
    return buckets, slot


def unbucket(buckets, assign, slot):
    """Inverse of `bucket_by_expert`: gather bucket rows back to tokens."""
    safe_slot = jnp.maximum(slot, 0)
    out = buckets[assign, safe_slot]
    return jnp.where((slot >= 0)[:, None], out, 0.0)


def moe_ffn(x, w1, w2, assign, capacity=None, block_t=64):
    """Full MoE FFN: bucket -> pallas expert GEMMs -> unbucket.

    Matches `ref.moe_ffn_ref` exactly for tokens within capacity.
    """
    e = w1.shape[0]
    t = x.shape[0]
    if capacity is None:
        capacity = t  # no drops
    # round capacity up to the block size
    capacity = ((capacity + block_t - 1) // block_t) * block_t
    buckets, slot = bucket_by_expert(x, assign, e, capacity)
    out_buckets = moe_ffn_bucketed(buckets, w1, w2, block_t=block_t)
    return unbucket(out_buckets, assign, slot)


def moe_ffn_dense(x, w1, w2, assign, capacity=None, block_t=64):
    """Pure-jnp *bucketed* MoE FFN — bitwise-equivalent computation to
    `moe_ffn` (same bucket/unbucket, dense einsum instead of the Pallas
    grid), fully differentiable and memory-efficient.

    Used as the backward path of the model's custom VJP: the per-token
    gather oracle in ref.py materializes [T, H, F] weight copies, which
    is correct but catastrophically slow at training shapes.
    """
    e = w1.shape[0]
    t = x.shape[0]
    if capacity is None:
        capacity = t
    capacity = ((capacity + block_t - 1) // block_t) * block_t
    buckets, slot = bucket_by_expert(x, assign, e, capacity)
    h = jnp.einsum("ech,ehf->ecf", buckets, w1)
    h = jax.nn.gelu(h)
    out_buckets = jnp.einsum("ecf,efh->ech", h, w2)
    return unbucket(out_buckets, assign, slot)


def vmem_bytes(block_t, h, f, dtype_bytes=4):
    """Estimated VMEM working set of one program instance (DESIGN.md
    §Perf): x block + w1 + w2 + h intermediate + output block."""
    return dtype_bytes * (block_t * h + h * f + f * h + block_t * f + block_t * h)


def mxu_utilization_estimate(block_t, h, f):
    """Fraction of MXU-aligned work: how close the GEMM tiles are to
    multiples of the 128x128 systolic tile."""
    def eff(dim):
        return dim / (((dim + 127) // 128) * 128)
    # two GEMMs: [Tb,H]x[H,F] and [Tb,F]x[F,H]
    g1 = eff(block_t) * eff(h) * eff(f)
    g2 = eff(block_t) * eff(f) * eff(h)
    return (g1 + g2) / 2.0
