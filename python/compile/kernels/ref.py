"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float assoc) reference
here; pytest + hypothesis sweep shapes/dtypes and assert allclose.
"""

import jax
import jax.numpy as jnp


def moe_ffn_ref(x, w1, w2, assign):
    """Reference MoE FFN with capacity-bucketed dense dispatch.

    Args:
      x:      [T, H]      token activations
      w1:     [E, H, F]   expert up-projections
      w2:     [E, F, H]   expert down-projections
      assign: [T] int32   expert id per token (top-1 routing; top-k is
                          handled by calling this k times with scaled
                          combine weights at the model level)

    Returns:
      [T, H] expert outputs gathered back to token order.
    """
    # gather each token's expert weights and apply its FFN:
    # y_t = gelu(x_t @ w1[e_t]) @ w2[e_t]
    w1_t = w1[assign]            # [T, H, F]
    w2_t = w2[assign]            # [T, F, H]
    h = jnp.einsum("th,thf->tf", x, w1_t)
    h = jax.nn.gelu(h)
    return jnp.einsum("tf,tfh->th", h, w2_t)


def attention_ref(q, k, v, causal=True):
    """Reference scaled-dot-product attention.

    q, k, v: [B, Hd, S, D]  (batch, heads, seq, head_dim)
    """
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def rmsnorm_ref(x, gamma, eps=1e-6):
    """Reference RMSNorm over the last axis."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma
