"""Pallas kernel: blocked causal attention (flash-style).

Grid over (batch*heads, q-block); each instance streams k/v blocks with
an online-softmax accumulator, so the VMEM working set is O(block_q *
(d + block_k)) instead of O(S^2) — the HBM<->VMEM schedule the paper's
GPU kernels express with threadblocks, restated via BlockSpec + fori.

interpret=True (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, q_block):
    """One (bh, q-block) program instance with online softmax."""
    q = q_ref[...]  # [Bq, D]
    s = k_ref.shape[0]
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    bq = q.shape[0]
    qi = pl.program_id(1)

    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    def body(ki, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(ki * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(ki * block_k, block_k), slice(None)))
        logits = (q @ k.T).astype(jnp.float32) * scale  # [Bq, Bk]
        if causal:
            q_pos = qi * q_block + jax.lax.iota(jnp.int32, bq)[:, None]
            k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
            logits = jnp.where(q_pos >= k_pos, logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # guard fully-masked rows (m_new = -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[:, None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    n_k = s // block_k
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, acc0))
    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal"))
def flash_attention(q, k, v, block_q=64, block_k=64, causal=True):
    """Blocked attention.

    q, k, v: [B, Hd, S, D]; returns [B, Hd, S, D].
    """
    b, hd, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    bh = b * hd
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // block_q)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, causal=causal, q_block=block_q
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bi, qi: (bi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda bi, qi: (bi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bi, qi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bi, qi: (bi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, hd, s, d)


def vmem_bytes(block_q, block_k, s, d, dtype_bytes=4):
    """VMEM working set per instance: q block + one k/v block + softmax
    state + accumulator. (k/v full rows are HBM-resident; streamed.)"""
    return dtype_bytes * (
        block_q * d + 2 * block_k * d + block_q * block_k + block_q * d + 2 * block_q
    )
