"""AOT lowering: JAX -> HLO text artifacts + manifest.

Emits (to --out-dir, default ../artifacts):
  train_step.hlo.txt   f(*params, *momenta, tokens, targets)
                         -> (*params', *momenta', loss[1])
  forward.hlo.txt      f(*params, tokens) -> (logits,)
  kernel_demo.hlo.txt  the bare Pallas MoE-FFN on demo shapes (quickstart)
  meta.json            parameter schema + model dims for the Rust runtime

HLO *text*, not `.serialize()`: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (behind the `xla` crate)
rejects; the text parser reassigns ids. See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: M.ModelConfig) -> str:
    specs = M.param_specs(cfg)
    param_args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in specs]
    mom_args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    fn = M.make_train_step(cfg)
    return to_hlo_text(jax.jit(fn).lower(*param_args, *mom_args, tok, tok))


def lower_forward(cfg: M.ModelConfig) -> str:
    specs = M.param_specs(cfg)
    param_args = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    fn = M.make_forward(cfg)
    return to_hlo_text(jax.jit(fn).lower(*param_args, tok))


def lower_kernel_demo() -> str:
    """Bare Pallas MoE-FFN: (x[64,32], w1[4,32,64], w2[4,64,32],
    assign[64]) -> (y[64,32],) — the quickstart round-trip artifact."""
    from compile.kernels import moe_ffn as moe_k

    def demo(x, w1, w2, assign):
        return (moe_k.moe_ffn(x, w1, w2, assign, block_t=16),)

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w1 = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    w2 = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    a = jax.ShapeDtypeStruct((64,), jnp.int32)
    return to_hlo_text(jax.jit(demo).lower(x, w1, w2, a))


def manifest(cfg: M.ModelConfig) -> dict:
    specs = M.param_specs(cfg)
    params = [
        {"name": n, "shape": list(s), "init_std": std} for n, s, std in specs
    ]
    # momenta follow the params in the artifact argument order, zero-init
    params += [
        {"name": f"mom.{n}", "shape": list(s), "init_std": 0.0}
        for n, s, _ in specs
    ]
    return {
        "batch": cfg.batch,
        "seq": cfg.seq,
        "vocab": cfg.vocab,
        "meta": {
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "experts": cfg.experts,
            "top_k": cfg.top_k,
        },
        "params": params,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--experts", type=int, default=8)
    ap.add_argument("--skip-train", action="store_true",
                    help="emit only forward + demo (faster)")
    args = ap.parse_args()

    cfg = M.ModelConfig(
        batch=args.batch,
        seq=args.seq,
        layers=args.layers,
        hidden=args.hidden,
        experts=args.experts,
    )
    os.makedirs(args.out_dir, exist_ok=True)

    def emit(name, text):
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>12,} chars -> {path}")

    emit("kernel_demo.hlo.txt", lower_kernel_demo())
    emit("forward.hlo.txt", lower_forward(cfg))
    if not args.skip_train:
        emit("train_step.hlo.txt", lower_train_step(cfg))
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(manifest(cfg), f, indent=1)
    print(f"wrote manifest -> {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
