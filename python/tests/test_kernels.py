"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py — the
CORE correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import moe_ffn as moe_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# --- MoE FFN ---------------------------------------------------------------

class TestMoeFfn:
    def test_matches_ref_basic(self):
        t, h, f, e = 64, 32, 64, 4
        x = rand(0, (t, h))
        w1 = rand(1, (e, h, f), scale=0.1)
        w2 = rand(2, (e, f, h), scale=0.1)
        assign = jax.random.randint(jax.random.PRNGKey(3), (t,), 0, e)
        y = moe_k.moe_ffn(x, w1, w2, assign, block_t=16)
        np.testing.assert_allclose(y, ref.moe_ffn_ref(x, w1, w2, assign), rtol=1e-4, atol=1e-5)

    @settings(deadline=None, max_examples=12)
    @given(
        t=st.sampled_from([16, 48, 64, 128]),
        h=st.sampled_from([8, 16, 32]),
        f=st.sampled_from([16, 32, 64]),
        e=st.sampled_from([2, 4, 8]),
        block_t=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_sweep(self, t, h, f, e, block_t, seed):
        x = rand(seed, (t, h))
        w1 = rand(seed + 1, (e, h, f), scale=0.1)
        w2 = rand(seed + 2, (e, f, h), scale=0.1)
        assign = jax.random.randint(jax.random.PRNGKey(seed + 3), (t,), 0, e)
        y = moe_k.moe_ffn(x, w1, w2, assign, block_t=block_t)
        np.testing.assert_allclose(
            y, ref.moe_ffn_ref(x, w1, w2, assign), rtol=2e-4, atol=2e-5
        )

    def test_dense_twin_is_bitwise_close(self):
        """moe_ffn (pallas) and moe_ffn_dense (jnp einsum) must agree so
        the custom VJP's forward/backward are consistent."""
        t, h, f, e = 128, 32, 64, 8
        x = rand(10, (t, h))
        w1 = rand(11, (e, h, f), scale=0.1)
        w2 = rand(12, (e, f, h), scale=0.1)
        assign = jax.random.randint(jax.random.PRNGKey(13), (t,), 0, e)
        for cap in [None, 32, 64]:
            a = moe_k.moe_ffn(x, w1, w2, assign, capacity=cap, block_t=16)
            b = moe_k.moe_ffn_dense(x, w1, w2, assign, capacity=cap, block_t=16)
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_capacity_drops_overflow_tokens(self):
        t, h, f, e = 64, 8, 16, 2
        x = rand(20, (t, h))
        w1 = rand(21, (e, h, f), scale=0.1)
        w2 = rand(22, (e, f, h), scale=0.1)
        assign = jnp.zeros((t,), jnp.int32)  # all tokens -> expert 0
        y = moe_k.moe_ffn(x, w1, w2, assign, capacity=16, block_t=16)
        # first 16 tokens computed, rest dropped to zero
        yr = ref.moe_ffn_ref(x, w1, w2, assign)
        np.testing.assert_allclose(y[:16], yr[:16], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(y[16:], 0.0, atol=1e-7)

    def test_bucket_roundtrip(self):
        t, h, e, cap = 32, 4, 4, 32
        x = rand(30, (t, h))
        assign = jax.random.randint(jax.random.PRNGKey(31), (t,), 0, e)
        buckets, slot = moe_k.bucket_by_expert(x, assign, e, cap)
        back = moe_k.unbucket(buckets, assign, slot)
        np.testing.assert_allclose(back, x, rtol=1e-6)

    def test_vmem_estimate_reasonable(self):
        # default training shape must fit a 16 MiB VMEM budget
        assert moe_k.vmem_bytes(64, 256, 1024) < 16 * 2**20

    def test_mxu_utilization_prefers_aligned(self):
        aligned = moe_k.mxu_utilization_estimate(128, 256, 1024)
        ragged = moe_k.mxu_utilization_estimate(65, 200, 1000)
        assert aligned == 1.0
        assert ragged < 0.8


# --- attention ---------------------------------------------------------------

class TestAttention:
    def test_matches_ref_basic(self):
        b, hd, s, d = 2, 4, 64, 16
        q, k, v = (rand(i, (b, hd, s, d)) for i in range(3))
        o = attn_k.flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v), rtol=1e-4, atol=1e-5
        )

    @settings(deadline=None, max_examples=10)
    @given(
        b=st.sampled_from([1, 2]),
        hd=st.sampled_from([1, 4]),
        s=st.sampled_from([16, 32, 64]),
        d=st.sampled_from([8, 16]),
        bq=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_sweep(self, b, hd, s, d, bq, bk, seed):
        if s % bq or s % bk:
            return
        q = rand(seed, (b, hd, s, d))
        k = rand(seed + 1, (b, hd, s, d))
        v = rand(seed + 2, (b, hd, s, d))
        o = attn_k.flash_attention(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v), rtol=2e-4, atol=2e-5
        )

    def test_non_causal_mode(self):
        b, hd, s, d = 1, 2, 32, 8
        q, k, v = (rand(40 + i, (b, hd, s, d)) for i in range(3))
        o = attn_k.flash_attention(q, k, v, block_q=16, block_k=16, causal=False)
        np.testing.assert_allclose(
            o, ref.attention_ref(q, k, v, causal=False), rtol=1e-4, atol=1e-5
        )

    def test_causality(self):
        """Perturbing future keys/values must not change earlier outputs."""
        b, hd, s, d = 1, 2, 32, 8
        q, k, v = (rand(50 + i, (b, hd, s, d)) for i in range(3))
        o1 = attn_k.flash_attention(q, k, v, block_q=16, block_k=16)
        k2 = k.at[:, :, s // 2 :, :].add(100.0)
        v2 = v.at[:, :, s // 2 :, :].add(-7.0)
        o2 = attn_k.flash_attention(q, k2, v2, block_q=16, block_k=16)
        np.testing.assert_allclose(
            o1[:, :, : s // 2], o2[:, :, : s // 2], rtol=1e-5, atol=1e-6
        )

    def test_softmax_rows_bounded(self):
        """Outputs are convex combinations of v rows."""
        b, hd, s, d = 1, 1, 32, 4
        q, k = rand(60, (b, hd, s, d)), rand(61, (b, hd, s, d))
        v = jnp.ones((b, hd, s, d))
        o = attn_k.flash_attention(q, k, v, block_q=16, block_k=16)
        np.testing.assert_allclose(o, 1.0, rtol=1e-5)


# --- rmsnorm ref sanity -------------------------------------------------------

def test_rmsnorm_unit_scale():
    x = rand(70, (8, 16))
    y = ref.rmsnorm_ref(x, jnp.ones((16,)))
    ms = jnp.mean(jnp.square(y), axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)
