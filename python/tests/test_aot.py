"""AOT path: lowering produces parseable HLO text + a consistent manifest."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.ModelConfig(layers=1, hidden=32, heads=2, experts=2, seq=16, batch=1, vocab=32)


def test_kernel_demo_lowers():
    text = aot.lower_kernel_demo()
    assert text.startswith("HloModule")
    # interpret-mode pallas must lower to plain HLO: no mosaic custom-calls
    assert "mosaic" not in text.lower()


def test_forward_lowers_plain_hlo():
    text = aot.lower_forward(TINY)
    assert text.startswith("HloModule")
    assert "mosaic" not in text.lower()
    # the 0.5.1 parser rejects the topk instruction; ensure we avoided it
    assert " topk(" not in text


def test_train_step_lowers_plain_hlo():
    text = aot.lower_train_step(TINY)
    assert text.startswith("HloModule")
    assert "mosaic" not in text.lower()
    assert " topk(" not in text


def test_manifest_consistent_with_specs():
    m = aot.manifest(TINY)
    specs = M.param_specs(TINY)
    assert len(m["params"]) == 2 * len(specs)  # params + momenta
    for entry, (name, shape, std) in zip(m["params"], specs):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == shape
        assert entry["init_std"] == std
    for entry, (name, shape, _) in zip(m["params"][len(specs):], specs):
        assert entry["name"] == f"mom.{name}"
        assert entry["init_std"] == 0.0
    assert m["batch"] == TINY.batch
    assert m["meta"]["experts"] == TINY.experts


def test_manifest_roundtrips_json():
    m = aot.manifest(TINY)
    assert json.loads(json.dumps(m)) == m


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "meta.json")),
    reason="artifacts not built",
)
def test_built_artifacts_exist_and_are_hlo():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in ["kernel_demo", "forward", "train_step"]:
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing {path} (run make artifacts)"
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")
