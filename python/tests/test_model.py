"""L2 correctness: model forward vs reference, train-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL = M.ModelConfig(layers=2, hidden=64, heads=4, experts=4, seq=32, batch=2, vocab=64)


@pytest.fixture(scope="module")
def small_setup():
    params = M.init_params(SMALL, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (SMALL.batch, SMALL.seq), 0, SMALL.vocab)
    return params, tokens


def test_param_specs_shapes(small_setup):
    params, _ = small_setup
    specs = M.param_specs(SMALL)
    assert len(params) == len(specs)
    for p, (_, shape, _) in zip(params, specs):
        assert p.shape == shape


def test_forward_shape(small_setup):
    params, tokens = small_setup
    logits = M.forward(SMALL, params, tokens)
    assert logits.shape == (SMALL.batch, SMALL.seq, SMALL.vocab)


def test_forward_close_to_reference(small_setup):
    """Pallas-kernel model vs reference-kernel model. Not exact: the
    model uses capacity-factor-2 buckets (drops) while the ref has no
    capacity limit — at init routing is near-uniform so drops are rare;
    tolerances account for the few dropped tokens."""
    params, tokens = small_setup
    lg = M.forward(SMALL, params, tokens)
    lr = M.forward_ref(SMALL, params, tokens)
    # median row must be tight; allow a small fraction of dropped rows
    err = np.abs(np.asarray(lg) - np.asarray(lr)).max(axis=-1).ravel()
    assert np.median(err) < 1e-4
    assert np.mean(err < 1e-2) > 0.9


def test_loss_is_scalar_and_near_uniform_at_init(small_setup):
    params, tokens = small_setup
    loss = M.loss_fn(SMALL, params, tokens, tokens)
    assert loss.shape == ()
    # tied embeddings bias the self-token logit, so init loss sits a bit
    # off uniform entropy; just require the right ballpark.
    assert abs(float(loss) - np.log(SMALL.vocab)) < 1.0


def test_train_step_reduces_loss_on_fixed_batch(small_setup):
    params, tokens = small_setup
    step = jax.jit(M.make_train_step(SMALL))
    moms = [jnp.zeros_like(p) for p in params]
    args = list(params) + list(moms)
    losses = []
    for _ in range(6):
        out = step(*args, tokens, tokens)
        args = list(out[:-1])
        losses.append(float(out[-1][0]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_output_arity(small_setup):
    params, tokens = small_setup
    n = len(params)
    step = M.make_train_step(SMALL)
    moms = [jnp.zeros_like(p) for p in params]
    out = step(*params, *moms, tokens, tokens)
    assert len(out) == 2 * n + 1
    assert out[-1].shape == (1,)


def test_momentum_linearity_dp_equivalence(small_setup):
    """Averaging (params', momenta') from a shared pre-step state equals
    stepping on the averaged gradient — the property the Rust
    DataParallelTrainer depends on."""
    params, tokens = small_setup
    tokens2 = jax.random.randint(jax.random.PRNGKey(9), tokens.shape, 0, SMALL.vocab)
    step = jax.jit(M.make_train_step(SMALL))
    moms = [jnp.zeros_like(p) for p in params]

    # replica A and B step on different shards from the same state
    out_a = step(*params, *moms, tokens, tokens)
    out_b = step(*params, *moms, tokens2, tokens2)
    n = len(params)
    avg_params = [(a + b) / 2 for a, b in zip(out_a[:n], out_b[:n])]

    # equivalent: one step on the mean gradient. mean grad step =
    # p - lr*(g_a+g_b)/2 = average of the two updates. Verify via loss
    # direction instead of reconstructing grads:
    la = M.loss_fn(SMALL, out_a[:n], tokens, tokens)
    lavg = M.loss_fn(SMALL, avg_params, tokens, tokens)
    # averaged params should still improve over init on shard A
    l0 = M.loss_fn(SMALL, params, tokens, tokens)
    assert float(lavg) < float(l0)
    assert np.isfinite(float(la))


def test_topk_manual_matches_lax_topk():
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 8))
    v1, i1 = M.topk_manual(x, 2)
    v2, i2 = jax.lax.top_k(x, 2)
    np.testing.assert_allclose(v1, v2, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_capacity_function():
    assert M._capacity(1024, 8) == 256
    assert M._capacity(8, 8) == 16  # floor
