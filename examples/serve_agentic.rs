//! Fleet-wide prefix cache + cache-aware routing on the agentic
//! multi-turn workload (ISSUE 7).
//!
//! The checked-in scenario (seed 42) serves six tenants of multi-turn
//! agent sessions: every session opens with its tenant's 1200-token
//! system prompt and every turn re-sends the whole conversation so
//! far. The cache-aware cell deduplicates those shared runs
//! fleet-wide in a radix-style `PrefixStore` (HBM → pooled supernode
//! DRAM → host tiers) and routes each session to the instance holding
//! its cached pages; the baseline is cache-blind session affinity,
//! which recomputes every prompt token. The headline: ≥1.3x
//! max-QPS-under-SLO and ≤0.5x recomputed tokens on the supernode
//! fabric, with the gap collapsing on legacy RoCE where a host-tier
//! fetch at 8 GB/s loses the bandwidth race against recompute.
//!
//! Every number printed here flows through the same
//! `ClusterReport::summary_kv()` rows the bench gate emits into
//! `BENCH_serving.json`.
//!
//! Run: `cargo run --release --example serve_agentic`
//!      `cargo run --release --example serve_agentic -- --rates 3`

use hyperparallel::serving::{
    agentic_rate_sweep, agentic_scenario, cluster_slo, max_qps_under_slo, run_agentic_scenario,
    ClusterFabric, ClusterReport, AGENTIC_COMPARE_RATE, AGENTIC_RATES,
};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn kv(rep: &ClusterReport, key: &str) -> f64 {
    rep.summary_kv()
        .into_iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("summary_kv misses {key}"))
}

fn main() {
    let args = Args::from_env();
    let n_rates = args.usize("rates", AGENTIC_RATES.len()).clamp(1, AGENTIC_RATES.len());
    let rates = &AGENTIC_RATES[..n_rates];
    let slo = cluster_slo();

    let mut rows = Vec::new();
    let mut gains = Vec::new();
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        let mut max_qps = Vec::new();
        for aware in [true, false] {
            let sc = agentic_scenario(fabric, aware);
            let points = agentic_rate_sweep(&sc, rates, &slo);
            let best = max_qps_under_slo(&points).map(|op| op.rate).unwrap_or(0.0);
            max_qps.push(best);

            let mut sc = agentic_scenario(fabric, aware);
            sc.workload = sc.workload.with_mean_rate(AGENTIC_COMPARE_RATE);
            let rep = run_agentic_scenario(&sc);
            rows.push(vec![
                format!("{fabric:?}"),
                (if aware { "cache-aware" } else { "cache-blind" }).to_string(),
                format!("{best:.0}"),
                format!("{:.0}", kv(&rep, "completed")),
                fmt_secs(kv(&rep, "p99_ttft")),
                fmt_secs(kv(&rep, "p99_tpot")),
                format!("{:.3}", kv(&rep, "prefix_hit_rate")),
                format!("{:.3}", kv(&rep, "tokens_recomputed_ratio")),
                format!("{:.0}", kv(&rep, "prefix_promotions")),
                format!("{:.0}", kv(&rep, "prefix_demotions")),
                fmt_secs(kv(&rep, "prefix_fetch_time")),
            ]);
        }
        gains.push((fabric, max_qps[0] / max_qps[1].max(1e-9)));
    }

    let wl = agentic_scenario(ClusterFabric::Supernode, true).workload;
    let n = wl.generate(8.0).len();
    println!(
        "agentic multi-turn scenario: {n} turns at {AGENTIC_COMPARE_RATE:.0} req/s over 8s, \
         sweep over {rates:?}, SLO p99 TTFT {} / TPOT {}\n",
        fmt_secs(slo.ttft_p99),
        fmt_secs(slo.tpot_p99)
    );
    print!(
        "{}",
        render_table(
            &[
                "fabric",
                "router",
                "max qps",
                "done",
                "p99 ttft",
                "p99 tpot",
                "hit rate",
                "recomp",
                "promo",
                "demo",
                "fetch"
            ],
            &rows
        )
    );
    for (fabric, gain) in gains {
        let note = match fabric {
            ClusterFabric::Supernode => " (gate >= 1.3x)",
            ClusterFabric::Legacy => " (collapses: host fetch loses to recompute)",
        };
        println!("\n{fabric:?}: cache-aware/blind max-QPS gain {gain:.2}x{note}");
    }
}
