//! E9 — agentic RL on the pooled supernode: cross-model concurrent
//! scheduling under the single controller vs gang-scheduled sync RL
//! (§3.3c: straggler elimination, +15% cluster utilization).
//!
//! Run: `cargo run --release --example rl_supernode -- --devices 64`

use hyperparallel::hypermpmd::{schedule_gang, schedule_single_controller, RlWorkload};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, Summary};

fn main() {
    let args = Args::from_env();
    let devices = args.usize("devices", 64);
    let iterations = args.usize("iterations", 8);

    let mut w = RlWorkload::paper_shape();
    w.models = args.usize("models", 4);
    w.rollouts_per_model = args.usize("rollouts", 64);
    w.rollout_sigma = args.f64("sigma", 0.8);

    println!(
        "RL workload: {} models x {} rollouts (lognormal sigma {}), update {}s, {} devices",
        w.models, w.rollouts_per_model, w.rollout_sigma, w.update_duration, devices
    );

    let mut gang_util = Summary::new();
    let mut sc_util = Summary::new();
    let mut gang_t = Summary::new();
    let mut sc_t = Summary::new();
    for it in 0..iterations {
        let tasks = w.generate(1000 + it as u64);
        let g = schedule_gang(&tasks, devices).expect("--devices must cover the models");
        let s = schedule_single_controller(&tasks, devices, devices / w.models)
            .expect("--devices must cover the models");
        gang_util.add(g.utilization);
        sc_util.add(s.utilization);
        gang_t.add(g.makespan);
        sc_t.add(s.makespan);
    }

    println!("\n                        gang (sync RL)   single controller");
    println!(
        "  iteration time        {:>14}   {:>17}",
        fmt_secs(gang_t.mean()),
        fmt_secs(sc_t.mean())
    );
    println!(
        "  cluster utilization   {:>13.1}%   {:>16.1}%",
        gang_util.mean() * 100.0,
        sc_util.mean() * 100.0
    );
    println!(
        "  utilization gain: {:+.1} pts (paper: +15%)",
        (sc_util.mean() - gang_util.mean()) * 100.0
    );
    println!(
        "  speedup: {:.2}x over {} iterations",
        gang_t.mean() / sc_t.mean(),
        iterations
    );

    // straggler sensitivity sweep
    println!("\nstraggler sensitivity (rollout lognormal sigma -> speedup):");
    for sigma in [0.2, 0.5, 0.8, 1.1, 1.4] {
        let mut ww = w.clone();
        ww.rollout_sigma = sigma;
        let tasks = ww.generate(7);
        let g = schedule_gang(&tasks, devices).expect("--devices must cover the models");
        let s = schedule_single_controller(&tasks, devices, devices / ww.models)
            .expect("--devices must cover the models");
        println!(
            "  sigma {sigma:>4}: gang {:>9} vs sc {:>9}  ({:.2}x)",
            fmt_secs(g.makespan),
            fmt_secs(s.makespan),
            g.makespan / s.makespan
        );
    }
}
