//! E14 — end-to-end validation: train the MoE transformer through the
//! full stack (Pallas kernels → JAX train-step → HLO artifact → PJRT →
//! Rust coordinator) on a synthetic bigram corpus and log the loss
//! curve. With `--dp N`, N replicas train on sharded batches and are
//! resynchronized by the real in-process all-reduce (1D data
//! parallelism — the execution mode HyperOffload's memory pooling
//! enables, §3.2).
//!
//! Run: `cargo run --release --example train_e2e -- --steps 300`

use hyperparallel::runtime::Runtime;
use hyperparallel::trainer::{bigram_entropy, render_curve, train, TrainOptions};
use hyperparallel::util::args::Args;
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 300);
    let dp = args.usize("dp", 1);

    let mut rt = Runtime::cpu(args.get_or("artifacts", "artifacts"))?;
    rt.load("train_step")?;
    let manifest = rt.manifest()?;
    println!(
        "model: {} tensors, {} state elements (params+momentum), batch={} seq={} vocab={}",
        manifest.params.len(),
        manifest.total_params(),
        manifest.batch,
        manifest.seq,
        manifest.vocab
    );

    let opts = TrainOptions {
        steps,
        seed: args.u64("seed", 42),
        dp,
        log_every: args.usize("log-every", 10),
    };
    println!("training {steps} steps (dp={dp}) ...\n");
    let report = train(&rt, &opts)?;

    println!("{}", render_curve(&report, 40));
    let h_bigram = bigram_entropy(manifest.vocab, opts.seed, 200_000);
    println!(
        "first loss {:.4} -> final loss {:.4} (corpus bigram entropy ≈ {:.4}, uniform = {:.4})",
        report.first_loss,
        report.final_loss,
        h_bigram,
        (manifest.vocab as f64).ln()
    );
    println!(
        "mean step {} | {:.0} tokens/s",
        fmt_secs(report.mean_step_seconds),
        report.tokens_per_second
    );
    anyhow::ensure!(
        report.final_loss < report.first_loss - 0.5,
        "loss did not decrease materially"
    );

    // dump the curve for EXPERIMENTS.md
    let mut root = JsonObj::new();
    root.insert(
        "curve",
        Json::Arr(
            report
                .curve
                .iter()
                .map(|p| {
                    let mut o = JsonObj::new();
                    o.insert("step", Json::from(p.step));
                    o.insert("loss", Json::from(p.loss as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    root.insert("final_loss", Json::from(report.final_loss as f64));
    root.insert("tokens_per_second", Json::from(report.tokens_per_second));
    std::fs::write("loss_curve.json", Json::Obj(root).pretty())?;
    println!("\nwrote loss_curve.json");
    Ok(())
}
