//! Co-scheduled training + serving on one supernode (ISSUE 5): the
//! device-lease broker arbitrating a 32-device pool between PR 4's
//! elastic serving cluster and an elastic training job, vs the static
//! half/half partition baseline.
//!
//! The checked-in scenario (seed 42): the diurnal serving swing leaves
//! deep troughs; the broker lets the trainer harvest them — paying a
//! real resharding cost over the actual fabric on every lease change.
//! On the supernode fabric co-scheduling holds the 0.5 s p99 TTFT
//! serving SLO while completing ≥1.4× the static partition's training
//! steps; on legacy RoCE the reshards (96 GiB of optimizer state over
//! ~1/15 the bandwidth) eat the harvest and the warm-up lag blows the
//! serving SLO — the fabric decides whether the supernode is one
//! logical computer or two.
//!
//! Run: `cargo run --release --example train_and_serve`
//!      `cargo run --release --example train_and_serve -- --fabric both --rate 30`

use hyperparallel::hypermpmd::coschedule::{
    cosched_scenario, cosched_slo, run_cosched, CoschedMode, CoschedReport,
    COSCHED_POOL_DEVICES, COSCHED_STATIC_SERVING,
};
use hyperparallel::serving::{ClusterFabric, AUTOSCALE_MEAN_RATE, AUTOSCALE_PERIOD};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn row(label: &str, rep: &CoschedReport, rate: f64) -> Vec<String> {
    let slo = cosched_slo();
    let op = rep.serving.operating_point(rate, &slo);
    vec![
        label.to_string(),
        format!("{}", op.completed),
        fmt_secs(op.p99_ttft),
        (if op.attains_slo { "yes" } else { "NO" }).to_string(),
        format!("{}", rep.train.steps_by_deadline),
        format!("{}", rep.train.reshards),
        fmt_secs(rep.train.reshard_seconds),
        format!("{}", rep.train.peak_devices),
        format!("{}", rep.broker.lease_misses),
    ]
}

fn main() {
    let args = Args::from_env();
    let rate = args.f64("rate", AUTOSCALE_MEAN_RATE);
    let fabric_arg = args.get_or("fabric", "both");
    let fabrics: Vec<(&str, ClusterFabric)> = match fabric_arg {
        "supernode" => vec![("supernode", ClusterFabric::Supernode)],
        "legacy" => vec![("legacy", ClusterFabric::Legacy)],
        _ => vec![
            ("supernode", ClusterFabric::Supernode),
            ("legacy", ClusterFabric::Legacy),
        ],
    };
    println!(
        "co-scheduled training + serving: {COSCHED_POOL_DEVICES}-device pool, diurnal \
         serving at {rate:.0} req/s mean over {AUTOSCALE_PERIOD:.0}s, static baseline \
         {COSCHED_STATIC_SERVING}/{COSCHED_STATIC_SERVING} split, SLO p99 TTFT {}\n",
        fmt_secs(cosched_slo().ttft_p99)
    );

    let mut rows = Vec::new();
    let mut gains = Vec::new();
    for (name, fabric) in &fabrics {
        let mut co = cosched_scenario(*fabric, CoschedMode::Cosched);
        let mut st = cosched_scenario(*fabric, CoschedMode::StaticPartition);
        co.workload.arrival = co.workload.arrival.with_mean_rate(rate);
        st.workload.arrival = st.workload.arrival.with_mean_rate(rate);
        let co_rep = run_cosched(&co);
        let st_rep = run_cosched(&st);
        let gain = co_rep.train.steps_by_deadline as f64
            / st_rep.train.steps_by_deadline.max(1) as f64;
        rows.push(row(&format!("{name} co-sched"), &co_rep, rate));
        rows.push(row(&format!("{name} static"), &st_rep, rate));
        gains.push((name.to_string(), gain));
    }
    print!(
        "{}",
        render_table(
            &[
                "scenario", "served", "p99 ttft", "slo", "train steps", "reshards",
                "reshard time", "peak devs", "lease misses",
            ],
            &rows
        )
    );
    println!();
    for (name, gain) in &gains {
        println!("  {name}: co-scheduling harvests {gain:.2}x the static partition's steps");
    }
    if gains.len() == 2 {
        println!(
            "\n  the fabric decides: supernode {:.2}x vs legacy {:.2}x — resharding over \
             RoCE eats the harvested troughs",
            gains[0].1, gains[1].1
        );
    }
}
