//! E6 — HyperOffload inference: serve a decode workload whose KV cache
//! outgrows HBM, using the paged cache + weight-streaming context
//! planner (§3.2: max context 71K → 123K at identical latency).
//!
//! Run: `cargo run --release --example offload_inference`

use hyperparallel::hyperoffload::kvcache::{ContextPlanner, KvCacheConfig, PagedKvCache};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_bytes, fmt_secs};

fn main() {
    let args = Args::from_env();
    let cfg = KvCacheConfig::llama8b_910c();

    println!("decode workload: llama-8b-class, kv {}/token, weights {}",
        fmt_bytes(cfg.kv_bytes_per_token), fmt_bytes(cfg.weight_bytes));

    // --- the paper's comparison -----------------------------------------
    let slo = ContextPlanner::baseline_latency(&cfg);
    let base = ContextPlanner::max_context_baseline(&cfg, slo);
    let (with, frac) = ContextPlanner::max_context_offload(&cfg, slo);
    println!("\nlatency SLO (baseline operating point): {}", fmt_secs(slo));
    println!("  baseline (all state in HBM):   max context {base} tokens");
    println!(
        "  hyperoffload (stream {:.0}% of weights from the DRAM pool): max context {with} tokens",
        frac * 100.0
    );
    println!(
        "  gain: {:+.0}%   (paper: 71K -> 123K, +70%)",
        (with as f64 / base as f64 - 1.0) * 100.0
    );

    // --- serve one long request through the paged cache -------------------
    // serve slightly past the hot-page budget so tail-demotion shows up
    let target = args.usize("tokens", with + 20 * 128);
    let mut cache = PagedKvCache::new(cfg.clone(), frac);
    for _ in 0..target {
        cache.append_token();
    }
    let (hbm, pool) = cache.bytes_by_home();
    println!(
        "\nserved {} tokens: {} pages ({} hot in HBM = {}, {} cold in pool = {}), {} demotions",
        cache.tokens(),
        cache.pages(),
        cache.hbm_pages(),
        fmt_bytes(hbm),
        cache.pages() - cache.hbm_pages(),
        fmt_bytes(pool),
        cache.pages_swapped_out
    );

    // --- SLO sweep: context vs latency, both policies ---------------------
    println!("\ncontext vs decode-step latency:");
    println!("{:>10} {:>16} {:>16}", "tokens", "baseline", "hyperoffload");
    for n in [16_000, 32_000, 64_000, 71_000, 96_000, 123_000] {
        let lb = if n <= base {
            fmt_secs(cfg.decode_latency(n, 0.0))
        } else {
            "OOM".to_string()
        };
        let lo = fmt_secs(cfg.decode_latency(n, frac));
        println!("{n:>10} {lb:>16} {lo:>16}");
    }
}
