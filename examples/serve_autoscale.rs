//! Elastic autoscaling on the diurnal swing: static peak provisioning
//! vs an SLO-driven autoscaler, on both fabrics, plus an injected
//! instance crash.
//!
//! The checked-in scenario (seed 42) offers a two-tenant diurnal mix
//! whose rate swings ≥4x between trough and peak. Static peak
//! provisioning keeps 9 instances on all day; the elastic cluster
//! starts at 4 and lets a queue-depth policy track the swing, paying a
//! model-load warm-up (16 GiB over the actual fabric tier) per
//! scale-up and draining KV out with the custody protocol per
//! scale-down. The headline: on the supernode fabric elastic scaling
//! holds the p99 TTFT SLO with ≥25% fewer instance-seconds; on the
//! legacy fabric the ~1.4 s RoCE warm-up lag blows the SLO. A crash
//! run shows zero requests lost and TTFT re-converging after the
//! autoscaler replaces the dead instance.
//!
//! Run: `cargo run --release --example serve_autoscale`
//!      `cargo run --release --example serve_autoscale -- --rate 30`

use hyperparallel::serving::{
    autoscale_crash_scenario, autoscale_scenario, autoscale_slo, autoscale_workload,
    run_cluster_scenario, ClusterFabric, ClusterReport, AUTOSCALE_MEAN_RATE, AUTOSCALE_PERIOD,
};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn row(label: &str, rep: &ClusterReport, rate: f64) -> Vec<String> {
    let slo = autoscale_slo();
    let op = rep.operating_point(rate, &slo);
    vec![
        label.to_string(),
        format!("{}", op.completed),
        format!("{}", op.rejected),
        fmt_secs(op.p99_ttft),
        fmt_secs(op.p99_tpot),
        format!("{:.1}", rep.instance_seconds),
        format!("{}", rep.scale_ups),
        format!("{}", rep.scale_downs),
        format!("{}", rep.crashes),
        (if op.attains_slo { "yes" } else { "NO" }).to_string(),
    ]
}

fn main() {
    let args = Args::from_env();
    let rate: f64 = args
        .get("rate")
        .map(|r| r.parse().expect("bad --rate"))
        .unwrap_or(AUTOSCALE_MEAN_RATE);
    let slo = autoscale_slo();
    let wl = autoscale_workload(rate);
    let swing = wl.arrival.swing_ratio(AUTOSCALE_PERIOD, 4800);
    let n = wl.generate(AUTOSCALE_PERIOD).len();
    println!(
        "diurnal autoscale scenario: mean {rate:.0} req/s, {swing:.1}x swing, {n} requests \
         over {AUTOSCALE_PERIOD:.0}s, SLO p99 TTFT {} / TPOT {}\n",
        fmt_secs(slo.ttft_p99),
        fmt_secs(slo.tpot_p99)
    );

    let mut rows = Vec::new();
    let mut saved = None;
    for fabric in [ClusterFabric::Supernode, ClusterFabric::Legacy] {
        let mut static_sc = autoscale_scenario(fabric, false);
        let mut elastic_sc = autoscale_scenario(fabric, true);
        static_sc.workload = wl.clone();
        elastic_sc.workload = wl.clone();
        let st = run_cluster_scenario(&static_sc);
        let el = run_cluster_scenario(&elastic_sc);
        if fabric == ClusterFabric::Supernode {
            saved = Some(1.0 - el.instance_seconds / st.instance_seconds);
        }
        rows.push(row(&format!("{fabric:?} static"), &st, rate));
        rows.push(row(&format!("{fabric:?} elastic"), &el, rate));
    }
    let mut crash_sc = autoscale_crash_scenario(ClusterFabric::Supernode);
    crash_sc.workload = wl.clone();
    let crash = run_cluster_scenario(&crash_sc);
    let crash_t = AUTOSCALE_PERIOD * 0.5;
    rows.push(row("Supernode elastic+crash", &crash, rate));
    print!(
        "{}",
        render_table(
            &[
                "deployment",
                "done",
                "rej",
                "p99 ttft",
                "p99 tpot",
                "inst-sec",
                "ups",
                "downs",
                "crashes",
                "slo"
            ],
            &rows
        )
    );

    if let Some(saved) = saved {
        println!(
            "\nheadline: elastic scaling saves {:.1}% instance-seconds vs static peak \
             provisioning on the supernode fabric (gate >= 25%)",
            saved * 100.0
        );
    }
    println!(
        "crash recovery: {} requeued, {} rejected; post-crash p99 TTFT (arrivals after \
         t+2s): {}",
        crash.crash_requeues,
        crash.serving.rejected,
        fmt_secs(
            crash
                .serving
                .ttft_pct_arriving_in(99.0, crash_t + 2.0, AUTOSCALE_PERIOD)
        )
    );
}
