//! Fleet-wide fault injection + recovery (ISSUE 6): the co-scheduled
//! training + serving pool riding out link degradation, a training
//! device failure, and random chaos schedules.
//!
//! Part 1 replays the checked-in seed-42 scenario — one `DeviceFail`
//! at t=18 s into the training tenant plus a 10× rack-tier
//! `LinkDegrade` window over [20, 26) s — against the fault-free run.
//! The router's retry/hedging keeps serving p99 TTFT within 2× of
//! fault-free with zero lost requests, and the trainer
//! checkpoint-restores losing at most one step (MTTR ≈ 40 ms).
//!
//! Part 2 sweeps `faults::chaos::random_plan` schedules (random link
//! windows + device fails + instance crashes) over seeds and checks
//! the global invariants on every one: request conservation, the
//! lease-ledger partition (free + serving-held + crashed + failed =
//! pool), page custody at drain, and tenant overlap-freedom.
//!
//! Run: `cargo run --release --example serve_chaos`
//!      `cargo run --release --example serve_chaos -- --seeds 4`

use hyperparallel::faults::chaos::CHAOS_SEEDS;
use hyperparallel::hypermpmd::coschedule::{
    assert_tenant_isolation, chaos_cosched_scenario, cosched_scenario, cosched_slo,
    fault_cosched_scenario, run_cosched, CoschedMode, CoschedReport, COSCHED_POOL_DEVICES,
};
use hyperparallel::serving::{ClusterFabric, AUTOSCALE_MEAN_RATE};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn ledger(rep: &CoschedReport) -> usize {
    rep.broker.free_at_end.len()
        + rep.serving.held_devices_at_end.len()
        + rep.serving.crashed_devices.len()
        + rep.broker.failed_at_end.len()
}

fn main() {
    let args = Args::from_env();
    let seeds = args.u64("seeds", CHAOS_SEEDS);

    println!(
        "part 1 — checked-in seed-42 scenario: DeviceFail at t=18s + 10x rack \
         degrade over [20s, 26s) on the {COSCHED_POOL_DEVICES}-device co-schedule\n"
    );
    let slo = cosched_slo();
    let clean = run_cosched(&cosched_scenario(
        ClusterFabric::Supernode,
        CoschedMode::Cosched,
    ));
    let fsc = fault_cosched_scenario();
    let submitted = fsc.workload.generate(fsc.horizon).len();
    let faulted = run_cosched(&fsc);
    let rows: Vec<Vec<String>> = [("fault-free", &clean), ("faulted", &faulted)]
        .iter()
        .map(|(label, rep)| {
            let op = rep.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
            vec![
                label.to_string(),
                format!("{}/{}", op.completed, submitted),
                fmt_secs(op.p99_ttft),
                format!("{}", rep.train.steps_by_deadline),
                format!("{}", rep.train.device_fails),
                format!("{}", rep.train.steps_lost),
                format!("{}", rep.train.restores),
                fmt_secs(rep.train.mttr_seconds),
                format!("{}", rep.serving.retries_scheduled),
                format!("{}", rep.serving.hedged),
            ]
        })
        .collect();
    print!(
        "{}",
        render_table(
            &[
                "run", "served", "p99 ttft", "steps", "fails", "lost", "restores", "mttr",
                "retries", "hedged",
            ],
            &rows
        )
    );
    let fop = faulted.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    let cop = clean.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    println!(
        "\n  p99 TTFT under faults: {:.2}x fault-free (gate <= 2.0x), {} request(s) lost, \
         {} step(s) lost to the fail (gate <= 1)\n",
        fop.p99_ttft / cop.p99_ttft,
        submitted - fop.completed,
        faulted.train.steps_lost,
    );

    println!("part 2 — chaos sweep: {seeds} random fault schedule(s), invariants asserted\n");
    let mut rows = Vec::new();
    for seed in 0..seeds {
        let cfg = chaos_cosched_scenario(seed);
        let submitted = cfg.workload.generate(cfg.horizon).len();
        // run_cosched itself asserts the lease partition and page
        // custody at drain; the checks below are the cross-tenant view
        let rep = run_cosched(&cfg);
        assert_tenant_isolation(&rep);
        assert_eq!(
            rep.serving.serving.outcomes.len() + rep.serving.serving.rejected as usize,
            submitted,
            "seed {seed}: requests lost"
        );
        assert!(rep.train.steps_lost <= rep.train.device_fails);
        assert_eq!(ledger(&rep), COSCHED_POOL_DEVICES, "seed {seed}: ledger");
        rows.push(vec![
            format!("{seed}"),
            format!("{}", cfg.cluster.faults.link_windows.len()),
            format!("{}", rep.train.device_fails),
            format!("{}", rep.serving.crashes),
            format!(
                "{}/{}",
                rep.serving.serving.outcomes.len(),
                submitted
            ),
            format!("{}", rep.train.steps_lost),
            format!("{}", rep.serving.retries_scheduled),
            format!("{}", rep.serving.hedged),
        ]);
    }
    print!(
        "{}",
        render_table(
            &[
                "seed", "windows", "fails", "crashes", "served", "lost steps", "retries",
                "hedged",
            ],
            &rows
        )
    );
    println!(
        "\n  all {seeds} schedule(s) conserved requests, pages, and leases — the pool \
         stays one logical computer under chaos"
    );
}
