//! E5 — HyperOffload training: Llama-8B-class single-rank step under
//! three memory policies (§3.2: 5.2s → 4.08s, ~20% gain; ND-SPMD →
//! 1D-DP).
//!
//! Run: `cargo run --release --example offload_training`

use hyperparallel::baselines::{nd_spmd_step, zero_offload_step};
use hyperparallel::hyperoffload::OffloadPolicy;
use hyperparallel::memory::TransferEngine;
use hyperparallel::supernode::Topology;
use hyperparallel::trainer::scenarios::OffloadTrainingScenario;
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_bytes, fmt_secs};

fn main() {
    let args = Args::from_env();
    let mut s = OffloadTrainingScenario::llama8b();
    println!(
        "workload: {} ({:.1}B params, {} training state/rank)",
        s.model.name,
        s.model.params() as f64 / 1e9,
        fmt_bytes(s.model.train_state().total())
    );
    let policy = OffloadPolicy::new(s.topo.devices[0].spec.hbm_bytes);
    let (without, with) = policy.min_model_parallel(&s.model.train_state());
    println!(
        "model-parallel degree required: {} without offload -> {} with HyperOffload (ND-SPMD -> 1D-DP)",
        without, with
    );

    let base = zero_offload_step(&s);
    let hyper = s.hyperoffload_step(args.usize("lookahead", 2));
    println!("\nper-rank step time:");
    println!("  ZeRO-style sync offload (PCIe):       {}", fmt_secs(base));
    println!("  HyperOffload (pipelined, UB pool):    {}", fmt_secs(hyper));
    println!(
        "  gain: {:.1}%  (paper: 5.2s -> 4.08s = 21.5%)",
        (base / hyper - 1.0) * 100.0
    );

    // ND-SPMD comparison needs a cluster that can fit the model
    s.topo = Topology::matrix384();
    if let Some(spmd) = nd_spmd_step(&s) {
        println!(
            "  best ND-SPMD plan on matrix384 (no offload): {} per step",
            fmt_secs(spmd)
        );
    }

    // lookahead sweep — the multi-level cache pipeline depth
    println!("\nprefetch lookahead sweep (UB pool):");
    for k in 1..=6 {
        let t = s.step_time(k, TransferEngine::supernode());
        println!(
            "  lookahead {k}: {}{}",
            fmt_secs(t),
            if k == 1 { "  (synchronous)" } else { "" }
        );
    }

    // fabric sensitivity: the same schedule on PCIe vs UB
    println!("\nfabric sensitivity (lookahead 2):");
    let pcie = s.step_time(2, TransferEngine::legacy_pcie());
    let ub = s.step_time(2, TransferEngine::supernode());
    println!("  PCIe-class pool: {}", fmt_secs(pcie));
    println!("  UB-class pool:   {} ({:.2}x)", fmt_secs(ub), pcie / ub);
}
