//! E8 + E12 — omni-modal training under HyperMPMD.
//!
//! Loads the paper's Listing-1 node-to-module mapping, runs the
//! omni-modal step under (a) static SPMD+PP groups and (b) HyperMPMD's
//! decoupled dynamic scheduling, reports bubbles and the training gain,
//! and writes Chrome traces of both schedules.
//!
//! Run: `cargo run --release --example omni_modal_mpmd`

use hyperparallel::hypermpmd::{
    omni_modal_example, schedule_dynamic, schedule_static, OmniModalWorkload, ProcessGroupMap,
};
use hyperparallel::supernode::{DeviceId, Topology};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let topo = Topology::matrix384();

    // --- Listing 1: node-to-module mapping -------------------------------
    let map = ProcessGroupMap::from_json(omni_modal_example(), topo.device_count())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("MPMD process groups (Listing 1):");
    for g in &map.groups {
        println!(
            "  {:<16} module={:<8} ranks [{:>3}, {:>3})  ({} devices)",
            g.name,
            g.module,
            g.rank_start,
            g.rank_end,
            g.len()
        );
    }
    println!(
        "covered {} / {} devices; device 33 belongs to '{}'",
        map.covered(),
        topo.device_count(),
        map.group_of(DeviceId(33)).unwrap().name
    );

    // --- E8: static vs dynamic scheduling --------------------------------
    let microbatches = args.usize("microbatches", 16);
    let w = OmniModalWorkload::paper_shape(microbatches);
    println!("\nomni-modal step: {} sub-modules x {microbatches} microbatches", w.modules.len());
    for m in &w.modules {
        println!("  {:<16} {}/microbatch", m.name, fmt_secs(m.time_per_microbatch));
    }

    let stat = schedule_static(&w);
    let dyn_ = schedule_dynamic(&w, w.modules.len());
    println!("\n                    static SPMD+PP    HyperMPMD dynamic");
    println!(
        "  step time         {:>14}    {:>17}",
        fmt_secs(stat.makespan),
        fmt_secs(dyn_.makespan)
    );
    println!(
        "  pipeline bubbles  {:>13.1}%    {:>16.1}%",
        stat.bubble_ratio * 100.0,
        dyn_.bubble_ratio * 100.0
    );
    println!(
        "  training gain: {:+.1}%  (paper: ~15%; bubbles 10-40% eliminated)",
        (stat.makespan / dyn_.makespan - 1.0) * 100.0
    );

    // --- traces -----------------------------------------------------------
    let dump = |name: &str, r: &hyperparallel::hypermpmd::ScheduleReport| {
        let mut events = Vec::new();
        for iv in r.sim.intervals() {
            use hyperparallel::util::json::{Json, JsonObj};
            let mut e = JsonObj::new();
            e.insert("name", Json::from(format!("task{}", iv.task.0)));
            e.insert("ph", Json::from("X"));
            e.insert("ts", Json::from(iv.start * 1e6));
            e.insert("dur", Json::from((iv.finish - iv.start) * 1e6));
            e.insert("pid", Json::from(0usize));
            e.insert("tid", Json::from(iv.resource.0));
            events.push(Json::Obj(e));
        }
        let path = format!("trace_{name}.json");
        std::fs::write(&path, hyperparallel::util::json::Json::Arr(events).dump()).unwrap();
        println!("wrote {path}");
    };
    dump("static", &stat);
    dump("dynamic", &dyn_);
    Ok(())
}
