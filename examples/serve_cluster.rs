//! Cluster serving: the fabric-decided prefill/decode disaggregation
//! crossover, searched over (mode × routing policy × fabric).
//!
//! Four batcher instances are placed across racks; arrivals flow
//! through the front-end router; in disaggregated mode each prompt's
//! KV pages migrate from a prefill instance to a decode instance at a
//! cost taken from the actual fabric tier. The sweep finds every
//! cell's max-QPS-under-SLO operating point and prints the headline:
//! disaggregation wins on the supernode fabric (KV migration over
//! pooled memory is near-free) and loses on the legacy fabric (the
//! staged copy steals decode iterations).
//!
//! Run: `cargo run --release --example serve_cluster`
//!      `cargo run --release --example serve_cluster -- --rates 10,20,40,80`

use hyperparallel::serving::{
    cluster_rate_sweep, cluster_slo, crossover_scenario, max_qps_under_slo, ClusterFabric,
    ClusterMode, OperatingPoint, RoutePolicy, CLUSTER_RATES,
};
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn csv_f64(s: &str) -> Vec<f64> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse().unwrap_or_else(|_| panic!("bad number '{p}'")))
        .collect()
}

fn main() {
    let args = Args::from_env();
    let rates = if let Some(r) = args.get("rates") {
        csv_f64(r)
    } else {
        CLUSTER_RATES.to_vec()
    };
    let slo = cluster_slo();

    let fabrics = [ClusterFabric::Supernode, ClusterFabric::Legacy];
    let modes = [ClusterMode::Colocated, ClusterMode::Disaggregated];
    let policies = [
        ("round-robin", RoutePolicy::RoundRobin),
        ("least-kv", RoutePolicy::LeastOutstandingKv),
    ];

    // One grid cell = (fabric, mode, policy); each cell's rate sweep
    // already fans out through sim::sweep, so the outer grid runs
    // sequentially over parallel inner sweeps (nesting parallel maps
    // would oversubscribe the machine for no wall-clock gain).
    let grid: Vec<(ClusterFabric, ClusterMode, &str, RoutePolicy)> = fabrics
        .iter()
        .flat_map(|&f| {
            modes.iter().flat_map(move |&m| {
                policies.iter().map(move |&(name, p)| (f, m, name, p))
            })
        })
        .collect();
    let sweeps: Vec<_> = grid
        .iter()
        .map(|&(fabric, mode, _, policy)| {
            let mut sc = crossover_scenario(fabric, mode);
            sc.cluster.route = policy;
            cluster_rate_sweep(&sc, &rates, &slo)
        })
        .collect();

    println!(
        "cluster crossover: {} cells x {} rates, SLO p99 TTFT {} / TPOT {}\n",
        grid.len(),
        rates.len(),
        fmt_secs(slo.ttft_p99),
        fmt_secs(slo.tpot_p99)
    );
    let rows: Vec<Vec<String>> = grid
        .iter()
        .zip(&sweeps)
        .map(|(&(fabric, mode, policy_name, _), points)| {
            let cell = |op: Option<OperatingPoint>| match op {
                Some(p) => vec![
                    format!("{:.0}", p.rate),
                    fmt_secs(p.p99_ttft),
                    fmt_secs(p.p99_tpot),
                    format!("{:.1}%", p.mean_utilization * 100.0),
                ],
                None => vec!["-".into(), "-".into(), "-".into(), "-".into()],
            };
            let mut row = vec![
                format!("{fabric:?}"),
                format!("{mode:?}"),
                policy_name.to_string(),
            ];
            row.extend(cell(max_qps_under_slo(points)));
            row
        })
        .collect();
    print!(
        "{}",
        render_table(
            &["fabric", "mode", "routing", "max qps", "p99 ttft", "p99 tpot", "util"],
            &rows
        )
    );

    // Headline from the least-kv cells.
    let find = |fabric, mode| {
        grid.iter()
            .position(|&(f, m, name, _)| f == fabric && m == mode && name == "least-kv")
            .and_then(|i| max_qps_under_slo(&sweeps[i]))
    };
    if let (Some(cs), Some(ds), Some(cl), Some(dl)) = (
        find(ClusterFabric::Supernode, ClusterMode::Colocated),
        find(ClusterFabric::Supernode, ClusterMode::Disaggregated),
        find(ClusterFabric::Legacy, ClusterMode::Colocated),
        find(ClusterFabric::Legacy, ClusterMode::Disaggregated),
    ) {
        println!(
            "\nheadline: supernode fabric flips the winner — disaggregation {:.2}x ahead on \
             the supernode ({:.0} vs {:.0} req/s), colocation {:.2}x ahead on legacy \
             ({:.0} vs {:.0} req/s)",
            ds.rate / cs.rate,
            ds.rate,
            cs.rate,
            cl.rate / dl.rate,
            cl.rate,
            dl.rate
        );
    }
}
