//! Quickstart: the whole three-layer stack in one file.
//!
//! 1. Load the AOT-compiled Pallas MoE-FFN demo artifact (L1, compiled
//!    by `make artifacts`) and execute it through the PJRT runtime.
//! 2. Verify the numbers against a native-Rust recomputation.
//! 3. Declare a HyperShard layout and let the planner pick a strategy.
//!
//! Run: `cargo run --release --example quickstart`

use hyperparallel::config::ModelDesc;
use hyperparallel::coordinator::Coordinator;
use hyperparallel::hypershard::{Layout, MapDim};
use hyperparallel::runtime::{literal_f32, literal_i32, to_f32, Runtime};
use hyperparallel::supernode::Topology;
use hyperparallel::util::rng::Rng;

/// Native recomputation of the kernel demo: y = gelu(x @ w1[e]) @ w2[e].
fn moe_ffn_native(
    x: &[f32],
    w1: &[f32],
    w2: &[f32],
    assign: &[i32],
    t: usize,
    h: usize,
    f: usize,
) -> Vec<f32> {
    let gelu = |v: f32| {
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
    };
    let mut out = vec![0f32; t * h];
    for ti in 0..t {
        let e = assign[ti] as usize;
        let mut hidden = vec![0f32; f];
        for fi in 0..f {
            let mut acc = 0f32;
            for hi in 0..h {
                acc += x[ti * h + hi] * w1[e * h * f + hi * f + fi];
            }
            hidden[fi] = gelu(acc);
        }
        for hi in 0..h {
            let mut acc = 0f32;
            for fi in 0..f {
                acc += hidden[fi] * w2[e * f * h + fi * h + hi];
            }
            out[ti * h + hi] = acc;
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    // --- 1. run the Pallas kernel artifact through PJRT ----------------
    let mut rt = Runtime::cpu("artifacts")?;
    rt.load("kernel_demo")?;
    println!("PJRT platform: {}", rt.platform());

    let (t, h, f, e) = (64usize, 32usize, 64usize, 4usize);
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..t * h).map(|_| rng.normal() as f32 * 0.5).collect();
    let w1: Vec<f32> = (0..e * h * f).map(|_| rng.normal() as f32 * 0.1).collect();
    let w2: Vec<f32> = (0..e * f * h).map(|_| rng.normal() as f32 * 0.1).collect();
    let assign: Vec<i32> = (0..t).map(|_| rng.below(e as u64) as i32).collect();

    let out = rt.execute(
        "kernel_demo",
        &[
            literal_f32(&[t, h], &x)?,
            literal_f32(&[e, h, f], &w1)?,
            literal_f32(&[e, f, h], &w2)?,
            literal_i32(&[t], &assign)?,
        ],
    )?;
    let y = to_f32(&out[0])?;

    // --- 2. verify against native Rust ---------------------------------
    let y_native = moe_ffn_native(&x, &w1, &w2, &assign, t, h, f);
    let max_err = y
        .iter()
        .zip(&y_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pallas-kernel vs native max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "kernel mismatch");
    println!("kernel round-trip OK (python wrote HLO once; rust executes)");

    // --- 3. declare a layout, plan a strategy --------------------------
    let layout = Layout::new(&[2, 4], &["dp", "tp"])?;
    let spec = layout.apply(&[MapDim::None, MapDim::Axis("tp")])?;
    println!(
        "\nLayout(2x4, dp/tp) weight tensor_map (None, tp): {} shards, replicated over {:?}",
        spec.num_shards, spec.replicated_axes
    );

    let coord = Coordinator::new(Topology::matrix384()).with_offload(true);
    let summary = coord.plan_model(&ModelDesc::llama_8b());
    println!("\nplanned on matrix384: {}", summary.explanation);
    Ok(())
}
