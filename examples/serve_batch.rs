//! Serving driver: load the forward artifact, serve batched inference
//! requests through the continuous batcher, report per-request latency
//! and aggregate throughput — batch-1 vs continuous batching.
//!
//! Optionally warm-starts from a short training run (`--train-steps N`)
//! so generations come from a model that has actually learned the
//! corpus' bigram structure.
//!
//! Run: `cargo run --release --example serve_batch -- --requests 16`

use hyperparallel::coordinator::{InferenceRequest, InferenceServer};
use hyperparallel::runtime::{Runtime, TrainExecutor};
use hyperparallel::trainer::Corpus;
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, Percentiles};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 16);
    let max_new = args.usize("max-new", 24);
    let train_steps = args.usize("train-steps", 0);

    let mut rt = Runtime::cpu(args.get_or("artifacts", "artifacts"))?;
    rt.load("forward")?;
    let manifest = rt.manifest()?;

    // optionally train first so the served model is non-random
    let trained_params = if train_steps > 0 {
        rt.load("train_step")?;
        let mut exec = TrainExecutor::new(manifest.clone(), 42);
        let mut corpus = Corpus::new(manifest.vocab, 42);
        println!("warm-starting: {train_steps} train steps ...");
        for _ in 0..train_steps {
            let (t, y) = corpus.batch(manifest.batch, manifest.seq);
            exec.step(&rt, &t, &y)?;
        }
        Some(exec.params()[..manifest.params.len() / 2].to_vec())
    } else {
        None
    };

    let mk_requests = |seed: u64| -> Vec<InferenceRequest> {
        let mut corpus = Corpus::new(manifest.vocab, seed);
        (0..n_requests as u64)
            .map(|id| {
                let (prompt, _) = corpus.batch(1, 8 + (id as usize % 24));
                InferenceRequest {
                    id,
                    prompt,
                    max_new_tokens: max_new,
                }
            })
            .collect()
    };

    let serve = |label: &str, batch_limit: usize| -> anyhow::Result<()> {
        let mut srv = InferenceServer::new(manifest.clone(), 42);
        if let Some(p) = &trained_params {
            srv.set_params(p.clone());
        }
        let reqs = mk_requests(7);
        let t0 = Instant::now();
        let mut total_tokens = 0usize;
        if batch_limit == 1 {
            // serial: one request at a time
            for r in reqs {
                srv.submit(r);
                total_tokens += srv.run_to_completion(&rt)?;
            }
        } else {
            for r in reqs {
                srv.submit(r);
            }
            total_tokens = srv.run_to_completion(&rt)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut lat = Percentiles::new();
        for c in &srv.completions {
            lat.add(c.latency);
        }
        println!(
            "{label:<22} {:>4} reqs  {:>6} tokens  wall {:>9}  {:>7.1} tok/s  p50 {:>9}  p95 {:>9}  occupancy {:>5.1}%",
            srv.completions.len(),
            total_tokens,
            fmt_secs(wall),
            total_tokens as f64 / wall,
            fmt_secs(lat.pct(50.0)),
            fmt_secs(lat.pct(95.0)),
            srv.occupancy() * 100.0
        );
        Ok(())
    };

    println!(
        "serving {n_requests} requests x {max_new} new tokens (model batch={} seq={})\n",
        manifest.batch, manifest.seq
    );
    serve("serial (batch=1)", 1)?;
    serve("continuous batching", manifest.batch)?;
    Ok(())
}
