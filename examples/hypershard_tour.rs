//! E10 + E11 — a tour of HyperShard's declarative programming model:
//! the Fig 6 Layout derivation, automatic collective insertion (Fig 5b),
//! Table 1's strategy dimensions, and the Table 2 planner sweep — with
//! the wall-clock cost of "strategy tuning" measured (paper: days →
//! hours; here: a cost-model sweep in milliseconds).
//!
//! Run: `cargo run --release --example hypershard_tour`

use hyperparallel::config::{ModelDesc, ModelFamily};
use hyperparallel::hypershard::{
    dimensions_for, explain, matmul, plan, Layout, MapDim, PlannerConfig,
};
use hyperparallel::supernode::{DeviceSpec, Fabric, Geometry, Topology};
use hyperparallel::util::stats::render_table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // --- Fig 6: Layout(device_matrix, alias_name)(tensor_map) ------------
    println!("== Fig 6: Layout derivation ==");
    let layout = Layout::new(&[2, 2], &["x", "y"])?;
    let spec = layout.apply(&[MapDim::Axis("x"), MapDim::Axis("y")])?;
    println!(
        "Layout((2,2), (x,y)) applied to tensor_map (x,y): shard counts {:?}",
        spec.shard_counts
    );
    for (rank, shard) in layout.placement(&spec).iter().enumerate() {
        println!("  rank {rank} holds shard {shard:?}");
    }

    // --- Fig 5b: automatic collective insertion ---------------------------
    println!("\n== Fig 5b: declarative propagation ==");
    let l = Layout::new(&[2, 4], &["dp", "tp"])?;
    let a = l.apply(&[MapDim::None, MapDim::Axis("tp")])?; // activations sharded on k
    let b = l.apply(&[MapDim::Axis("tp"), MapDim::None])?; // row-parallel weight
    let p = matmul(&a, &b);
    for c in &p.comms {
        println!("  inserted {} over axes {:?}: {}", c.kind.name(), c.axes, c.reason);
    }

    // --- Table 1: strategy dimensions by model family ---------------------
    println!("\n== Table 1: strategies by model ==");
    let rows: Vec<Vec<String>> = [
        ModelFamily::DenseTransformer,
        ModelFamily::SparseMoe,
        ModelFamily::Diffusion,
        ModelFamily::LongSequence,
        ModelFamily::Rl,
    ]
    .iter()
    .map(|f| vec![f.name().to_string(), dimensions_for(*f).join(", ")])
    .collect();
    print!("{}", render_table(&["Model & Algorithm", "Strategy"], &rows));

    // --- Table 2: planner sweep across clusters ---------------------------
    println!("\n== Table 2: strategies by cluster (auto-planned) ==");
    let clusters: Vec<(&str, Topology, ModelDesc)> = vec![
        (
            "Single machine (8 die)",
            Topology::new(
                Geometry { racks: 1, boards_per_rack: 1, dies_per_board: 8 },
                Fabric::supernode(),
                DeviceSpec::ascend_910c(),
            ),
            ModelDesc::dense_30b(),
        ),
        (
            "Single machine (16 die)",
            Topology::new(
                Geometry { racks: 1, boards_per_rack: 2, dies_per_board: 8 },
                Fabric::supernode(),
                DeviceSpec::ascend_910c(),
            ),
            ModelDesc::dense_50b(),
        ),
        (
            "Matrix384 hyperplane",
            Topology::matrix384(),
            ModelDesc::deepseek_v3_like(),
        ),
    ];
    let cfg = PlannerConfig { allow_offload: true, ..Default::default() };
    let mut table = Vec::new();
    let t0 = Instant::now();
    for (name, topo, model) in &clusters {
        let plans = plan(model, topo, &cfg);
        let best = plans.first().expect("no plan");
        table.push(vec![
            name.to_string(),
            model.name.clone(),
            best.strategy.describe(),
            format!("{:.3}s", best.step_time),
        ]);
    }
    let dt = t0.elapsed();
    print!(
        "{}",
        render_table(&["Cluster", "Model", "Planned strategy", "Est. step"], &table)
    );
    println!(
        "\nfull strategy search across 3 clusters took {:?} — the paper's",
        dt
    );
    println!("days-of-manual-tuning cycle becomes a declarative cost-model sweep (E10).");

    // detailed explain of one plan
    let best = plan(&clusters[2].2, &clusters[2].1, &cfg);
    println!("\ntop-3 candidates on matrix384 for {}:", clusters[2].2.name);
    for c in best.iter().take(3) {
        println!("  {}", explain(c));
    }
    Ok(())
}
