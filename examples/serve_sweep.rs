//! Serving sweep: find the max-QPS-under-SLO operating point over
//! arrival rate × fleet size × offload fraction, on the discrete-event
//! serving simulator (traffic → continuous batcher → KV pages → SLOs).
//!
//! The headline comparison reproduces HyperOffload §3.2 at the serving
//! level: streaming a fraction of the weights from the pooled DRAM
//! frees HBM for KV pages, so the fleet holds more concurrent context
//! and sustains a higher request rate at the same p99 latency SLO.
//!
//! Run: `cargo run --release --example serve_sweep`
//!      `cargo run --release --example serve_sweep -- --fleets 1,2,4 --offload 0,0.1,0.2`

use hyperparallel::serving::{max_qps_under_slo, rate_sweep, smoke_scenario, smoke_slo};
use hyperparallel::sim::SweepSpec;
use hyperparallel::util::args::Args;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn csv_f64(s: &str) -> Vec<f64> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse().unwrap_or_else(|_| panic!("bad number '{p}'")))
        .collect()
}

fn csv_usize(s: &str) -> Vec<usize> {
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.trim().parse().unwrap_or_else(|_| panic!("bad integer '{p}'")))
        .collect()
}

fn main() {
    let args = Args::from_env();
    let fleets = csv_usize(args.get_or("fleets", "1,2"));
    let fracs = csv_f64(args.get_or("offload", "0,0.2"));
    let rates = csv_f64(args.get_or("rates", "15,30,45,60,75,90,105,120"));
    let slo = smoke_slo();

    println!(
        "serving sweep: {} fleets x {} offload fracs x {} rates, SLO p99 TTFT {} / TPOT {}\n",
        fleets.len(),
        fracs.len(),
        rates.len(),
        fmt_secs(slo.ttft_p99),
        fmt_secs(slo.tpot_p99)
    );

    // One grid cell = one (fleet, frac) sweep over the rate axis; the
    // rate sweep itself already fans out via sim::sweep, so the outer
    // grid runs parallel cells over parallel inner sweeps.
    let cells: Vec<(String, (usize, f64))> = fleets
        .iter()
        .flat_map(|&fleet| {
            fracs
                .iter()
                .map(move |&frac| (format!("fleet{fleet}/offload{frac}"), (fleet, frac)))
        })
        .collect();
    let sweeps = SweepSpec::with_labels("cell", cells).run(|&(fleet, frac)| {
        rate_sweep(&smoke_scenario(rates[0], frac, fleet), &rates, &slo)
    });

    for row in &sweeps {
        let (fleet, frac) = row.point;
        let points = &row.value;
        println!("--- fleet={fleet} offload_frac={frac} ---");
        let rows: Vec<Vec<String>> = points
            .iter()
            .map(|p| {
                vec![
                    format!("{:.0}", p.rate),
                    format!("{}", p.completed),
                    format!("{}", p.rejected),
                    format!("{:.1}", p.admitted_qps),
                    format!("{:.1}", p.goodput),
                    fmt_secs(p.p50_ttft),
                    fmt_secs(p.p99_ttft),
                    fmt_secs(p.p99_tpot),
                    format!("{:.1}%", p.mean_utilization * 100.0),
                    format!("{}", p.peak_context_tokens),
                    format!("{}", p.preemptions),
                    if p.attains_slo { "yes".into() } else { "no".into() },
                ]
            })
            .collect();
        print!(
            "{}",
            render_table(
                &[
                    "rate", "done", "rej", "qps", "goodput", "p50 ttft", "p99 ttft",
                    "p99 tpot", "util", "peak ctx", "preempt", "slo"
                ],
                &rows
            )
        );
        match max_qps_under_slo(points) {
            Some(op) => println!(
                "max QPS under SLO: {:.0} req/s (peak context {} tokens)\n",
                op.rate, op.peak_context_tokens
            ),
            None => println!("no rate attains the SLO\n"),
        }
    }

    // Headline: baseline vs best offload fraction on the largest fleet.
    if fracs.len() >= 2 {
        let fleet = *fleets.last().unwrap();
        let find = |frac: f64| {
            sweeps
                .iter()
                .find(|r| r.point == (fleet, frac))
                .and_then(|r| max_qps_under_slo(&r.value))
        };
        let base = find(fracs[0]);
        let best = fracs[1..]
            .iter()
            .filter_map(|&fr| find(fr))
            .max_by(|a, b| a.rate.total_cmp(&b.rate));
        if let (Some(b), Some(o)) = (base, best) {
            println!(
                "headline (fleet={fleet}): pool-offload sustains {:.0} req/s vs {:.0} baseline \
                 ({:.2}x QPS, {:.2}x peak context) at the same p99 SLO",
                o.rate,
                b.rate,
                o.rate / b.rate,
                o.peak_context_tokens as f64 / b.peak_context_tokens as f64
            );
        }
    }
}
