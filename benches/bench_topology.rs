//! E4 — supernode fabric characteristics (paper §2.3).
//!
//! Paper: the UB supernode delivers ~15× the cross-machine bandwidth of
//! PCIe/Ethernet clusters and cuts single-hop latency 2 µs → 200 ns
//! (10×). We regenerate the link table and a message-size sweep on both
//! fabrics, plus collective-cost crossovers.

use hyperparallel::collectives::{cost, Algorithm};
use hyperparallel::graph::CollectiveKind;
use hyperparallel::supernode::{DeviceId, Fabric, LinkTier, Topology};
use hyperparallel::util::bench::section;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E4: link tiers — paper: 15x bandwidth, 10x lower hop latency");
    let sn = Fabric::supernode();
    let lg = Fabric::legacy();
    let rows: Vec<Vec<String>> = [
        ("board", sn.board, lg.board),
        ("rack", sn.rack, lg.rack),
        ("cross-rack", sn.cross_rack, lg.cross_rack),
    ]
    .iter()
    .map(|(name, s, l)| {
        vec![
            name.to_string(),
            format!("{:.0} GB/s / {}", s.bandwidth / 1e9, fmt_secs(s.hop_latency)),
            format!("{:.1} GB/s / {}", l.bandwidth / 1e9, fmt_secs(l.hop_latency)),
            format!("{:.1}x / {:.0}x", s.bandwidth / l.bandwidth, l.hop_latency / s.hop_latency),
        ]
    })
    .collect();
    print!(
        "{}",
        render_table(&["tier", "supernode (bw/hop)", "legacy (bw/hop)", "advantage"], &rows)
    );

    section("p2p message-size sweep (cross-rack)");
    let topo_sn = Topology::matrix384();
    let topo_lg = Topology::legacy_cluster(48);
    let a = DeviceId(0);
    let b = DeviceId(100);
    println!("{:>12} {:>14} {:>14} {:>8}", "bytes", "supernode", "legacy", "ratio");
    for exp in [10, 14, 18, 22, 26, 30] {
        let bytes = (1u64 << exp) as f64;
        let ts = topo_sn.p2p_time(a, b, bytes);
        let tl = topo_lg.p2p_time(a, b, bytes);
        println!(
            "{:>12} {:>14} {:>14} {:>7.1}x",
            1u64 << exp,
            fmt_secs(ts),
            fmt_secs(tl),
            tl / ts
        );
    }

    section("collective algorithm selection (64-rank all-to-all / all-reduce)");
    let group: Vec<DeviceId> = (0..64).map(DeviceId).collect();
    println!(
        "{:>12} {:>12} {:>22} {:>22}",
        "bytes", "collective", "supernode", "legacy"
    );
    for (kind, bytes) in [
        (CollectiveKind::AllReduce, 1e4),
        (CollectiveKind::AllReduce, 1e8),
        (CollectiveKind::AllToAll, 1e6),
        (CollectiveKind::AllToAll, 1e8),
        (CollectiveKind::AllGather, 1e8),
    ] {
        let cs = cost(&topo_sn, kind, bytes, &group);
        let cl = cost(&topo_lg, kind, bytes, &group);
        let alg = |a: Algorithm| match a {
            Algorithm::Ring => "ring",
            Algorithm::Tree => "tree",
            Algorithm::FullMeshDirect => "mesh",
        };
        println!(
            "{bytes:>12.0} {:>12} {:>15} ({:>4}) {:>15} ({:>4})",
            kind.name(),
            fmt_secs(cs.time),
            alg(cs.algorithm),
            fmt_secs(cl.time),
            alg(cl.algorithm),
        );
    }

    section("tier resolution sanity (matrix384 geometry)");
    let t = &topo_sn;
    for (a, b, expect) in [
        (0usize, 1usize, LinkTier::Board),
        (0, 8, LinkTier::Rack),
        (0, 48, LinkTier::CrossRack),
    ] {
        let tier = t.tier_between(DeviceId(a), DeviceId(b));
        println!("  npu{a} <-> npu{b}: {tier:?} (expected {expect:?})");
        assert_eq!(tier, expect);
    }
}
