//! E1 + E2 + E10 — HyperShard: Tables 1 and 2 plus the
//! strategy-tuning-time claim (§3.4: new-algorithm parallelization
//! < 1 day, tuning days → hours; here the search is a cost-model sweep
//! measured in microseconds).

use hyperparallel::config::{ModelDesc, ModelFamily};
use hyperparallel::hypershard::{dimensions_for, plan, Layout, MapDim, PlannerConfig};
use hyperparallel::supernode::{DeviceSpec, Fabric, Geometry, Topology};
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::stats::render_table;

fn main() {
    // --- Table 1 ----------------------------------------------------------
    section("E1 (Table 1): strategies by model");
    let rows: Vec<Vec<String>> = [
        (ModelFamily::DenseTransformer, "DP, PP, TP, SP"),
        (ModelFamily::SparseMoe, "DP, PP, TP, SP, EP"),
        (ModelFamily::Diffusion, "DP, FSDP"),
        (ModelFamily::LongSequence, "SP, CP"),
        (ModelFamily::Rl, "MPMD"),
    ]
    .iter()
    .map(|(f, paper)| {
        vec![
            f.name().to_string(),
            paper.to_string(),
            dimensions_for(*f).join(", "),
        ]
    })
    .collect();
    print!(
        "{}",
        render_table(&["Model & Algorithm", "Paper strategy", "Ours"], &rows)
    );

    // --- Table 2 ----------------------------------------------------------
    section("E2 (Table 2): strategies by cluster (auto-planned)");
    let cfg = PlannerConfig {
        allow_offload: true,
        max_tp: 16, // the paper's Table 2 considers TP degrees up to 16
        ..Default::default()
    };
    let mk = |racks, boards, dies, fabric: Fabric, spec: DeviceSpec| {
        Topology::new(
            Geometry {
                racks,
                boards_per_rack: boards,
                dies_per_board: dies,
            },
            fabric,
            spec,
        )
    };
    let cases: Vec<(&str, &str, Topology, ModelDesc)> = vec![
        (
            "Single machine (8 die)",
            "TP8, PP for the rest",
            mk(1, 1, 8, Fabric::supernode(), DeviceSpec::ascend_910c()),
            ModelDesc::dense_30b(),
        ),
        (
            "Single machine (16 die)",
            "TP16, reduced PP",
            mk(1, 2, 8, Fabric::supernode(), DeviceSpec::ascend_910c()),
            ModelDesc::dense_50b(),
        ),
        (
            "Legacy 16-die (2 servers)",
            "(TP must stay intra-board)",
            mk(1, 2, 8, Fabric::legacy(), DeviceSpec::a100_80g()),
            ModelDesc::dense_50b(),
        ),
        (
            "8k-die hyperplane",
            "topology-aware TP16, reduced PP",
            mk_topo_8k(),
            ModelDesc::dense_50b(),
        ),
        (
            "Matrix384 (MoE)",
            "(EP over the DP dimension)",
            Topology::matrix384(),
            ModelDesc::deepseek_v3_like(),
        ),
    ];
    let mut rows = Vec::new();
    for (name, paper, topo, model) in &cases {
        let best = plan(model, topo, &cfg).into_iter().next().unwrap();
        rows.push(vec![
            name.to_string(),
            paper.to_string(),
            best.strategy.describe(),
            format!("{:.2}s", best.step_time),
        ]);
    }
    print!(
        "{}",
        render_table(&["Cluster", "Paper", "Planned", "Est. step"], &rows)
    );

    // --- E10: tuning-time claim --------------------------------------------
    section("E10: strategy derivation + search wall time (paper: days -> hours)");
    let layout = Layout::new(&[2, 4, 8], &["dp", "pp", "tp"]).unwrap();
    run("layout derivation (Fig 6, rank-3 tensor)", 10, 1000, || {
        std::hint::black_box(
            layout
                .apply(&[MapDim::Axis("dp"), MapDim::None, MapDim::Axis("tp")])
                .unwrap()
                .num_shards,
        );
    });
    let topo = Topology::matrix384();
    let model = ModelDesc::deepseek_v3_like();
    run("full strategy search (moe-671b on matrix384)", 3, 50, || {
        std::hint::black_box(plan(&model, &topo, &cfg).len());
    });
    let t8 = mk_topo_8k();
    run("full strategy search (moe-671b on 8k-die hyperplane)", 1, 10, || {
        std::hint::black_box(plan(&model, &t8, &cfg).len());
    });
}

fn mk_topo_8k() -> Topology {
    Topology::new(
        Geometry {
            racks: 128,
            boards_per_rack: 8,
            dies_per_board: 8,
        },
        Fabric::supernode(),
        DeviceSpec::ascend_910c(),
    )
}
