//! E7 — intra-card comm masking (paper §3.3a, Fig 4a).
//!
//! Paper: HyperMPMD raises the MoE communication-masking ratio from the
//! traditional ~60% to ~90% (DeepSeek-V3: EP comm = 17% of execution at
//! 61% masking). We regenerate the baseline-vs-HyperMPMD comparison and
//! sweep chunk granularity and comm:compute ratio — both sweeps fanned
//! across `sim::sweep` workers (set `HP_SWEEP_THREADS=1` to force the
//! sequential path).

use hyperparallel::hypermpmd::{
    baseline_masking, chunk_sweep, comm_ratio_sweep, hypermpmd_masking, MoeLayerLoad,
};
use hyperparallel::util::bench::{maybe_write_json, run, section};
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E7: comm masking ratio — paper 60% -> 90%");
    let load = MoeLayerLoad::deepseek_like();
    let base = baseline_masking(load, 8);
    let hyper = hypermpmd_masking(load, 8, 16);

    let rows = vec![
        vec![
            "masking ratio".into(),
            "~60%".into(),
            "~90%".into(),
            format!("{:.1}%", base.masking_ratio * 100.0),
            format!("{:.1}%", hyper.masking_ratio * 100.0),
        ],
        vec![
            "stack makespan".into(),
            "-".into(),
            "-".into(),
            fmt_secs(base.makespan),
            format!("{} ({:.2}x)", fmt_secs(hyper.makespan), base.makespan / hyper.makespan),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["metric", "paper base", "paper hyper", "ours base", "ours hyper"],
            &rows
        )
    );

    section("chunk-granularity sweep (intra-card MPMD depth, parallel)");
    let chunk_counts = [1usize, 2, 4, 8, 16, 32];
    let reports = chunk_sweep(load, 8, &chunk_counts, true);
    println!("{:>8} {:>12} {:>12}", "chunks", "masking", "makespan");
    for (&chunks, r) in chunk_counts.iter().zip(&reports) {
        println!(
            "{chunks:>8} {:>11.1}% {:>12}",
            r.masking_ratio * 100.0,
            fmt_secs(r.makespan)
        );
    }

    section("comm:compute ratio sweep (when can 90% masking survive?, parallel)");
    let fracs = [0.1, 0.2, 0.34, 0.5, 0.8, 1.2];
    let base_shape = MoeLayerLoad {
        expert_compute: 80e-3,
        vector_compute: 20e-3,
        dispatch_comm: 0.0,
        combine_comm: 0.0,
    };
    println!("{:>12} {:>12} {:>12}", "comm/compute", "baseline", "hypermpmd");
    for (frac, b, h) in comm_ratio_sweep(base_shape, 50e-3, 8, &fracs) {
        println!(
            "{frac:>12.2} {:>11.1}% {:>11.1}%",
            b.masking_ratio * 100.0,
            h.masking_ratio * 100.0
        );
    }

    section("harness timing");
    let mut results = Vec::new();
    results.push(run("schedule 8-layer stack, 16 chunks", 2, 20, || {
        std::hint::black_box(hypermpmd_masking(load, 8, 16).masking_ratio);
    }));
    results.push(run("chunk sweep x6 via sim::sweep", 1, 10, || {
        std::hint::black_box(chunk_sweep(load, 8, &chunk_counts, true).len());
    }));
    maybe_write_json(&results);
}
