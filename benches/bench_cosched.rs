//! Co-scheduling smoke bench — wall-clock throughput of the
//! broker-mediated two-tenant DES, plus the deterministic virtual-time
//! crossover metrics CI gates on.
//!
//! Like `bench_serving`, two result classes go into
//! `BENCH_cosched.json` (`BENCH_JSON=<path>`): `"benches"` (wall-clock
//! timings, archived, not gated) and `"metrics"` — the ISSUE 5
//! crossover numbers (training-step gain vs the static half/half
//! partition per fabric, serving p99 TTFT under co-scheduling). The
//! simulators are deterministic, so the metrics are bit-identical on
//! every machine; `tools/bench_regression.py` gates them against
//! `BENCH_baseline.json` alongside the serving metrics. The same
//! presets are asserted (more tightly) by
//! `rust/tests/cosched_scenarios.rs`, so a green test suite implies a
//! green gate.

use hyperparallel::hypermpmd::coschedule::{
    cosched_comparison, cosched_scenario, cosched_slo, fault_cosched_scenario, run_cosched,
    CoschedMode,
};
use hyperparallel::serving::{ClusterFabric, AUTOSCALE_MEAN_RATE};
use hyperparallel::util::bench::{run, section, smoke, to_json, BenchResult};
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::stats::fmt_secs;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    section("co-scheduled DES wall-clock (serving + trainer + broker)");
    let iters = if smoke() { 2 } else { 5 };
    let sc = cosched_scenario(ClusterFabric::Supernode, CoschedMode::Cosched);
    let n_reqs = sc.workload.generate(sc.horizon).len();
    results.push(run(
        &format!("cosched sim diurnal {n_reqs} reqs + elastic trainer"),
        1,
        iters,
        || {
            std::hint::black_box(run_cosched(&sc).train.steps);
        },
    ));
    let st = cosched_scenario(ClusterFabric::Supernode, CoschedMode::StaticPartition);
    results.push(run(
        &format!("static-partition sim diurnal {n_reqs} reqs"),
        1,
        iters,
        || {
            std::hint::black_box(run_cosched(&st).train.steps);
        },
    ));

    section("co-scheduling crossover (virtual time — deterministic, CI-gated)");
    let slo = cosched_slo();
    let mut metrics = JsonObj::new();
    let mut gains = Vec::new();
    for (name, fabric) in [
        ("supernode", ClusterFabric::Supernode),
        ("legacy", ClusterFabric::Legacy),
    ] {
        let cmp = cosched_comparison(fabric);
        let cop = cmp.cosched.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
        let gain = cmp.step_gain();
        println!(
            "  {name:<10} co-sched {:>3} vs static {:>3} steps ({gain:.2}x)  \
             serving p99 ttft {:>10}  reshards {:>3} ({:>8} on fabric)  slo {}",
            cmp.cosched.train.steps_by_deadline,
            cmp.static_partition.train.steps_by_deadline,
            fmt_secs(cop.p99_ttft),
            cmp.cosched.train.reshards,
            fmt_secs(cmp.cosched.train.reshard_seconds),
            if cop.attains_slo { "yes" } else { "no" }
        );
        metrics.insert(
            format!("cosched.{name}.steps_gain"),
            Json::from(gain),
        );
        metrics.insert(
            format!("cosched.{name}.steps_by_deadline"),
            Json::from(cmp.cosched.train.steps_by_deadline as f64),
        );
        metrics.insert(
            format!("cosched.{name}.serving_p99_ttft_s"),
            Json::from(cop.p99_ttft),
        );
        metrics.insert(
            format!("cosched.{name}.reshard_seconds"),
            Json::from(cmp.cosched.train.reshard_seconds),
        );
        gains.push(gain);
    }
    println!(
        "\n  step-gain crossover: supernode {:.2}x vs legacy {:.2}x \
         (gates: >= 1.40 / <= 1.10)",
        gains[0], gains[1]
    );

    section("fault injection + recovery (virtual time — deterministic, CI-gated)");
    // The ISSUE 6 seed-42 scenario: one training DeviceFail at t=18 s
    // plus a 10x rack-tier degrade window over [20, 26) s, layered on
    // the supernode co-schedule. Same preset as
    // rust/tests/fault_scenarios.rs, which asserts the gated bounds
    // more tightly — green tests imply a green gate.
    let clean = run_cosched(&sc);
    let fsc = fault_cosched_scenario();
    let submitted = fsc.workload.generate(fsc.horizon).len();
    let faulted = run_cosched(&fsc);
    let fop = faulted.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    let cop = clean.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
    let completed_frac = fop.completed as f64 / submitted as f64;
    let p99_ratio = fop.p99_ttft / cop.p99_ttft;
    println!(
        "  faulted   {:>4}/{submitted} reqs  p99 ttft {:>10} ({p99_ratio:.2}x fault-free)  \
         retries {} hedged {}",
        fop.completed,
        fmt_secs(fop.p99_ttft),
        faulted.serving.retries_scheduled,
        faulted.serving.hedged,
    );
    println!(
        "  trainer   {} device fail(s), {} step(s) lost, {} restore(s) ({} on fabric), \
         mttr {}  steps {} vs fault-free {}",
        faulted.train.device_fails,
        faulted.train.steps_lost,
        faulted.train.restores,
        fmt_secs(faulted.train.restore_seconds),
        fmt_secs(faulted.train.mttr_seconds),
        faulted.train.steps_by_deadline,
        clean.train.steps_by_deadline,
    );
    metrics.insert("faults.cosched.completed_frac", Json::from(completed_frac));
    metrics.insert("faults.cosched.p99_ttft_ratio", Json::from(p99_ratio));
    metrics.insert(
        "faults.cosched.steps_lost",
        Json::from(faulted.train.steps_lost as f64),
    );
    metrics.insert(
        "faults.cosched.mttr_s",
        Json::from(faulted.train.mttr_seconds),
    );
    // Archived (not gated): the raw recovery ledger for the trajectory.
    metrics.insert(
        "faults.cosched.device_fails",
        Json::from(faulted.train.device_fails as f64),
    );
    metrics.insert(
        "faults.cosched.restores",
        Json::from(faulted.train.restores as f64),
    );
    metrics.insert(
        "faults.cosched.restore_seconds",
        Json::from(faulted.train.restore_seconds),
    );
    metrics.insert(
        "faults.cosched.retries",
        Json::from(faulted.serving.retries_scheduled as f64),
    );
    metrics.insert(
        "faults.cosched.hedged",
        Json::from(faulted.serving.hedged as f64),
    );
    metrics.insert(
        "faults.cosched.steps_by_deadline",
        Json::from(faulted.train.steps_by_deadline as f64),
    );

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = JsonObj::new();
        root.insert("benches", to_json(&results));
        root.insert("metrics", Json::Obj(metrics));
        match std::fs::write(&path, Json::Obj(root).pretty()) {
            Ok(()) => println!("\nbench json written to {path}"),
            Err(e) => {
                eprintln!("\nbench json write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
