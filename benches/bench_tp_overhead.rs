//! E3 — TP communication share (paper §2.2).
//!
//! Paper: "the data traffic overhead of TP accounts for 52.9% training
//! time in a typical training setting" on PCIe/Ethernet clusters —
//! the bottleneck the supernode removes. We regenerate the fraction on
//! both fabrics and sweep TP degree.

use hyperparallel::sim::SweepSpec;
use hyperparallel::supernode::Topology;
use hyperparallel::trainer::scenarios::TpOverheadScenario;
use hyperparallel::util::bench::section;
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E3: TP traffic share of step time — paper: 52.9% on legacy");
    let s = TpOverheadScenario::paper_setting();
    let legacy = TpOverheadScenario::legacy_4die_servers();
    let supernode = Topology::matrix384();

    let (c_l, x_l, f_l) = s.measure(&legacy);
    let (c_s, x_s, f_s) = s.measure(&supernode);
    let rows = vec![
        vec![
            "legacy (PCIe/Eth)".into(),
            fmt_secs(c_l),
            fmt_secs(x_l),
            format!("{:.1}%", f_l * 100.0),
            "52.9%".into(),
        ],
        vec![
            "supernode (UB)".into(),
            fmt_secs(c_s),
            fmt_secs(x_s),
            format!("{:.1}%", f_s * 100.0),
            "(removed)".into(),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["fabric", "TP comm", "compute", "TP share", "paper"],
            &rows
        )
    );
    println!("legacy/supernode TP-share ratio: {:.1}x", f_l / f_s);

    section("TP-degree sweep (share of step time, both fabrics in parallel)");
    let fabrics = [("legacy", legacy), ("supernode", supernode)];
    println!("{:>6} {:>12} {:>12}", "tp", "legacy", "supernode");
    let rows = SweepSpec::over("tp", vec![2usize, 4, 8, 16, 32]).run(|&tp| {
        let s = TpOverheadScenario {
            tp,
            ..TpOverheadScenario::paper_setting()
        };
        s.fabric_sweep(&fabrics)
    });
    for row in rows {
        let fracs = row.value;
        println!(
            "{:>6} {:>11.1}% {:>11.1}%",
            row.point,
            fracs[0].1 * 100.0,
            fracs[1].1 * 100.0
        );
    }
}
