//! E9 — cross-model concurrent scheduling for RL (paper §3.3c, Fig 4c).
//!
//! Paper: the single controller eliminates stragglers and raises
//! cluster-wide utilization by ~15% on multi-task RL. We regenerate the
//! gang-vs-single-controller comparison and sweep straggler heaviness
//! and cluster size, with the per-seed iterations fanned across
//! `sim::sweep` workers (`hypermpmd::seed_sweep`).

use hyperparallel::hypermpmd::{schedule_single_controller, seed_sweep, RlWorkload};
use hyperparallel::util::bench::{maybe_write_json, run, section};
use hyperparallel::util::stats::{render_table, Summary};

fn mean_over_seeds(
    w: &RlWorkload,
    devices: usize,
    seeds: std::ops::Range<u64>,
) -> (Summary, Summary, Summary, Summary) {
    let seeds: Vec<u64> = seeds.collect();
    let (mut gu, mut su, mut gt, mut st) =
        (Summary::new(), Summary::new(), Summary::new(), Summary::new());
    for (g, s) in seed_sweep(w, &seeds, devices, devices / w.models).expect("valid device count") {
        gu.add(g.utilization);
        su.add(s.utilization);
        gt.add(g.makespan);
        st.add(s.makespan);
    }
    (gu, su, gt, st)
}

fn main() {
    section("E9: RL cluster utilization — paper: +15% w/ single controller");
    let w = RlWorkload::paper_shape();
    let (gu, su, gt, st) = mean_over_seeds(&w, 64, 0..16);

    let rows = vec![
        vec![
            "cluster utilization".into(),
            "baseline".into(),
            "+15%".into(),
            format!("{:.1}%", gu.mean() * 100.0),
            format!("{:.1}% ({:+.1} pts)", su.mean() * 100.0, (su.mean() - gu.mean()) * 100.0),
        ],
        vec![
            "iteration time".into(),
            "-".into(),
            "stragglers gone".into(),
            format!("{:.2} s", gt.mean()),
            format!("{:.2} s ({:.2}x)", st.mean(), gt.mean() / st.mean()),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["metric", "paper gang", "paper sc", "ours gang", "ours sc"],
            &rows
        )
    );

    section("straggler-heaviness sweep (lognormal sigma of rollout durations)");
    println!("{:>8} {:>12} {:>12} {:>10}", "sigma", "gang util", "sc util", "speedup");
    for sigma in [0.2, 0.4, 0.6, 0.8, 1.0, 1.2] {
        let mut ww = w.clone();
        ww.rollout_sigma = sigma;
        let (gu, su, gt, st) = mean_over_seeds(&ww, 64, 0..8);
        println!(
            "{sigma:>8.1} {:>11.1}% {:>11.1}% {:>9.2}x",
            gu.mean() * 100.0,
            su.mean() * 100.0,
            gt.mean() / st.mean()
        );
    }

    section("cluster-size sweep");
    println!("{:>8} {:>12} {:>12}", "devices", "gang util", "sc util");
    for devices in [16, 32, 64, 128, 256] {
        let (gu, su, _, _) = mean_over_seeds(&w, devices, 0..8);
        println!(
            "{devices:>8} {:>11.1}% {:>11.1}%",
            gu.mean() * 100.0,
            su.mean() * 100.0
        );
    }

    section("harness timing");
    let mut results = Vec::new();
    let tasks = w.generate(3);
    results.push(run("single-controller schedule (256 rollouts, 64 dev)", 2, 50, || {
        std::hint::black_box(
            schedule_single_controller(&tasks, 64, 16)
                .expect("valid device count")
                .makespan,
        );
    }));
    let seeds: Vec<u64> = (0..16).collect();
    results.push(run("16-seed gang+sc sweep via sim::sweep", 1, 10, || {
        std::hint::black_box(seed_sweep(&w, &seeds, 64, 16).expect("valid device count").len());
    }));
    maybe_write_json(&results);
}
