//! Ablations over the design choices DESIGN.md calls out:
//! (a) activation policy: HyperOffload's pooled offload+recompute vs
//!     classic √L checkpointing;
//! (b) resharding: training→inference layout transitions and the RL
//!     actor weight sync, supernode vs legacy fabric;
//! (c) collective algorithm choice: full-mesh direct vs forcing ring.

use hyperparallel::collectives;
use hyperparallel::graph::CollectiveKind;
use hyperparallel::hyperoffload::{plan_recompute, sqrt_checkpointing, LayerActs, RecomputeConfig};
use hyperparallel::hypershard::{
    actor_weight_sync_time, plan_reshard, reshard_time, Layout, MapDim,
};
use hyperparallel::supernode::{DeviceId, Topology};
use hyperparallel::util::bench::section;
use hyperparallel::util::stats::{fmt_bytes, fmt_secs, render_table};

fn main() {
    // --- (a) activation policy ablation ---------------------------------
    section("ablation A: activation policy (llama-8b-like, 32 layers)");
    let layers: Vec<LayerActs> = (0..32)
        .map(|_| LayerActs {
            bytes: 2 << 30,
            recompute_flops: 30e12,
        })
        .collect();
    println!(
        "{:>14} {:>22} {:>22}",
        "HBM budget", "hyperoffload overhead", "sqrt-ckpt overhead"
    );
    for budget_gib in [8u64, 16, 32, 48, 64] {
        let cfg = RecomputeConfig {
            hbm_budget: budget_gib << 30,
            pool_bw: 200e9,
            compute_flops: 150e12,
            overlap: 0.9,
        };
        let ours = plan_recompute(&layers, &cfg);
        let sqrt = sqrt_checkpointing(&layers, &cfg);
        println!(
            "{:>14} {:>22} {:>22}",
            fmt_bytes(budget_gib << 30),
            fmt_secs(ours.overhead_s),
            fmt_secs(sqrt.overhead_s)
        );
    }

    // --- (b) resharding ----------------------------------------------------
    section("ablation B: resharding (train layout -> inference layout)");
    let l = Layout::new(&[4, 8], &["dp", "tp"]).unwrap();
    let train = l.apply(&[MapDim::Axis("tp"), MapDim::None]).unwrap();
    let infer_rep = l.apply(&[MapDim::None, MapDim::None]).unwrap();
    let infer_dp = l.apply(&[MapDim::Axis("dp"), MapDim::None]).unwrap();
    let group: Vec<DeviceId> = (0..32).map(DeviceId).collect();
    let w = 16e9; // 8B params bf16
    let cases = [
        ("tp-shard -> replicated", plan_reshard(&train, &infer_rep)),
        ("tp-shard -> dp-shard", plan_reshard(&train, &infer_dp)),
        ("replicated -> dp-shard", plan_reshard(&infer_rep, &infer_dp)),
    ];
    let sn = Topology::matrix384();
    let lg = Topology::legacy_cluster(8);
    let mut rows = Vec::new();
    for (name, plan) in &cases {
        let steps: Vec<String> = plan.steps.iter().map(|s| s.kind.name().to_string()).collect();
        rows.push(vec![
            name.to_string(),
            steps.join(" + "),
            fmt_secs(reshard_time(plan, &sn, &group, w, 8)),
            fmt_secs(reshard_time(plan, &lg, &group, w, 8)),
        ]);
    }
    print!(
        "{}",
        render_table(&["transition", "collectives", "supernode", "legacy"], &rows)
    );

    section("ablation B2: RL actor weight sync (16-way learner, 3 actor groups)");
    let learner: Vec<DeviceId> = (0..16).map(DeviceId).collect();
    let actors: Vec<Vec<DeviceId>> = (1..4)
        .map(|g| (g * 16..(g + 1) * 16).map(DeviceId).collect())
        .collect();
    for (name, topo) in [("supernode", &sn), ("legacy", &lg)] {
        let t = actor_weight_sync_time(topo, &learner, &actors, w, 16);
        println!("  {name:<12} {}", fmt_secs(t));
    }

    // --- (c) collective algorithm choice -----------------------------------
    section("ablation C: algorithm choice on the supernode (64-rank, 128 MiB)");
    let g64: Vec<DeviceId> = (0..64).map(DeviceId).collect();
    for kind in [
        CollectiveKind::AllReduce,
        CollectiveKind::AllGather,
        CollectiveKind::AllToAll,
    ] {
        let c = collectives::cost(&sn, kind, 128e6, &g64);
        println!(
            "  {:<14} chosen {:?}: {}",
            kind.name(),
            c.algorithm,
            fmt_secs(c.time)
        );
    }
}
