//! Auto-tuner bench (ISSUE 10) — wall-clock cost of the generate →
//! prune → simulate → refine loop, plus the deterministic acceptance
//! ratios CI gates on.
//!
//! Two result classes go into `BENCH_autotune.json` (`BENCH_JSON=`):
//! `"benches"` (wall-clock timings, archived, not gated) and
//! `"metrics"` — virtual-time ratios of the tuned strategy against the
//! hand-written presets on the checked-in seed-42 scenarios:
//!
//!   - planner: best *predicted* cost over the Matrix384 MoE lattice
//!     vs `plan()`'s best step time (identical lattice + cost model,
//!     so the ratio is exactly 1.0);
//!   - cosched pool: tuned lease vs the full 32-device broker lease on
//!     the homogeneous pool (nothing can beat the full lease → 1.0);
//!   - mixed-generation / slow-rack fleets: tuned lease vs the best
//!     hand preset (the preset group is in the tuner's seed ladder and
//!     lowers to the identical device group, so the ratio is <= 1.0).
//!
//! Every ratio is guaranteed by construction — prune_ratio >= 1.0
//! keeps the best-predicted candidate alive, and the budget truncation
//! keeps the lowest-predicted prefix — so the `autotune.*` gates in
//! `BENCH_baseline.json` pin them with zero tolerance. The same bounds
//! are asserted (more tightly) by `rust/tests/autotune_scenarios.rs`.

use hyperparallel::config::ModelDesc;
use hyperparallel::hypermpmd::{cosched_train_job, COSCHED_POOL_DEVICES, FLEET_SLOW_RACK_DERATE};
use hyperparallel::hypershard::{
    autotune, plan, AutoTuneConfig, ElasticObjective, PlannerConfig, PlannerObjective,
};
use hyperparallel::supernode::{DeviceSpec, Fabric, Fleet, Geometry, Topology};
use hyperparallel::util::bench::{run, section, smoke, to_json, BenchResult};
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::summary::insert_summary;

/// The co-scheduled training pool as a single-pool fleet (the same
/// shape `rust/tests/autotune_scenarios.rs` checks).
fn cosched_pool_fleet() -> Fleet {
    let topo = Topology::new(
        Geometry {
            racks: 4,
            boards_per_rack: 1,
            dies_per_board: 8,
        },
        Fabric::supernode(),
        DeviceSpec::ascend_910c(),
    );
    assert_eq!(topo.device_count(), COSCHED_POOL_DEVICES);
    Fleet::single(topo)
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut metrics = JsonObj::new();
    let cfg = AutoTuneConfig::default();
    let iters = if smoke() { 1 } else { 3 };
    let mut all_within_budget = true;

    // --- planner objective: Matrix384 MoE lattice -----------------------
    section("planner auto-search (matrix384, moe-671b)");
    // the Table 2 planner setting bench_hypershard uses for this cell
    let pcfg = PlannerConfig {
        allow_offload: true,
        max_tp: 16,
        ..Default::default()
    };
    let pobj = PlannerObjective::new(ModelDesc::deepseek_v3_like(), Topology::matrix384(), pcfg);
    results.push(run("autotune planner lattice (matrix384 moe)", 1, iters, || {
        std::hint::black_box(autotune(&pobj, &cfg).ranked.len());
    }));
    let preport = autotune(&pobj, &cfg);
    let plan_best = plan(&pobj.model, &pobj.topo, &pobj.cfg)
        .iter()
        .map(|c| c.step_time)
        .fold(f64::INFINITY, f64::min);
    let best_pred = preport
        .ranked
        .iter()
        .map(|c| c.predicted)
        .fold(f64::INFINITY, f64::min);
    let best = preport.best().expect("planner search found no candidate");
    println!(
        "  best '{}' predicted {:.3}s simulated {:.3}s; plan() best {:.3}s; \
         {} simulated / {} generated",
        best.label, best.predicted, best.simulated, plan_best, preport.simulated, preport.generated
    );
    metrics.insert(
        "autotune.planner.best_predicted_vs_plan_ratio",
        Json::from(best_pred / plan_best),
    );
    insert_summary(&mut metrics, "autotune.planner", &preport);
    all_within_budget &= preport.simulated <= preport.budget;

    // --- elastic objective: the three fleet lease scenarios -------------
    section("elastic lease auto-search (cosched pool + PR 9 fleets)");
    let cells: Vec<(&str, Fleet)> = vec![
        ("cosched", cosched_pool_fleet()),
        ("fleet_mixed", Fleet::mixed_generations()),
        ("fleet_slow_rack", Fleet::slow_rack(FLEET_SLOW_RACK_DERATE)),
    ];
    for (name, fleet) in cells {
        let job = cosched_train_job();
        // hand-written preset leases: the full fleet, and (for the
        // multi-pool fleet) the fast pool alone
        let full = job.step_time_fleet(&fleet, &fleet.all_devices(), true);
        let mut preset = full;
        if fleet.pool_count() > 1 {
            preset = preset.min(job.step_time_fleet(&fleet, &fleet.pool_devices(0), true));
        }
        let obj = ElasticObjective::new(job, fleet, true);
        results.push(run(&format!("autotune elastic lease ({name})"), 1, iters, || {
            std::hint::black_box(autotune(&obj, &cfg).ranked.len());
        }));
        let report = autotune(&obj, &cfg);
        let best = report.best().expect("elastic search found no candidate");
        println!(
            "  {name:<16} best '{}' {:.4}s vs preset {:.4}s ({} simulated)",
            best.label, best.simulated, preset, report.simulated
        );
        let key = if name == "cosched" {
            "autotune.cosched.best_vs_full_lease_ratio".to_string()
        } else {
            format!("autotune.{name}.best_vs_preset_ratio")
        };
        metrics.insert(key, Json::from(best.simulated / preset));
        insert_summary(&mut metrics, &format!("autotune.{name}"), &report);
        all_within_budget &= report.simulated <= report.budget;
    }

    let within = if all_within_budget { 1.0 } else { 0.0 };
    metrics.insert("autotune.budget_respected", Json::from(within));

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = JsonObj::new();
        root.insert("benches", to_json(&results));
        root.insert("metrics", Json::Obj(metrics));
        match std::fs::write(&path, Json::Obj(root).pretty()) {
            Ok(()) => println!("\nbench json written to {path}"),
            Err(e) => {
                eprintln!("\nbench json write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
