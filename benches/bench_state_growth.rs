//! E13 (Fig 1) — growth of parameter + intermediate-state complexity
//! across model eras.
//!
//! The paper's Figure 1 motivates HyperOffload: the bytes of weights,
//! gradients, optimizer moments, activations, and KV caches that a
//! framework must place and migrate keep growing. We regenerate the
//! figure's series from the state-accounting model.

use hyperparallel::config::ModelDesc;
use hyperparallel::memory::{StateBudget, StateKind};
use hyperparallel::supernode::DeviceSpec;
use hyperparallel::util::bench::section;
use hyperparallel::util::stats::{fmt_bytes, render_table};

fn main() {
    section("E13 (Fig 1): training-state growth across model eras");
    let eras: Vec<(&str, StateBudget)> = vec![
        (
            "CV small (25M)",
            StateBudget::training(25_000_000, 50, 2048, 64, 1, false),
        ),
        (
            "NLP bert-large (340M)",
            StateBudget::training(340_000_000, 24, 1024, 32, 512, false),
        ),
        (
            "LLM llama-8b",
            ModelDesc::llama_8b().train_state(),
        ),
        (
            "LLM dense-50b",
            ModelDesc::dense_50b().train_state(),
        ),
        (
            "MoE deepseek-v3-like",
            ModelDesc::deepseek_v3_like().train_state(),
        ),
    ];

    let hbm = DeviceSpec::ascend_910c().hbm_bytes;
    let mut rows = Vec::new();
    for (name, b) in &eras {
        rows.push(vec![
            name.to_string(),
            fmt_bytes(b.weights),
            fmt_bytes(b.optimizer),
            fmt_bytes(b.activations),
            fmt_bytes(b.total()),
            format!("{:.1}x", b.total() as f64 / hbm as f64),
        ]);
    }
    print!(
        "{}",
        render_table(
            &["era / model", "weights", "optimizer", "activations", "total", "x 64GiB HBM"],
            &rows
        )
    );

    section("inference KV-cache growth with context length (llama-8b)");
    let m = ModelDesc::llama_8b();
    println!("{:>10} {:>14} {:>12}", "context", "kv bytes", "x HBM");
    for ctx in [4_096, 32_768, 71_000, 123_000, 262_144, 1_048_576] {
        let b = m.infer_state(ctx);
        println!(
            "{ctx:>10} {:>14} {:>11.2}x",
            fmt_bytes(b.kv_cache),
            (b.kv_cache + b.weights) as f64 / hbm as f64
        );
    }

    section("state classes managed per era (count of live classes)");
    for (name, b) in &eras {
        let live: Vec<&str> = StateKind::all()
            .into_iter()
            .filter(|k| b.get(*k) > 0)
            .map(|k| k.name())
            .collect();
        println!("  {name:<24} {}", live.join(", "));
    }
}
