//! Serving simulator smoke bench — wall-clock throughput of the DES
//! itself, plus the deterministic virtual-time SLO metrics CI gates on.
//!
//! Two result classes go into `BENCH_serving.json`
//! (`BENCH_JSON=<path>`):
//!
//! - `"benches"` — wall-clock timings of the simulator (machine
//!   dependent, archived for the cross-PR perf trajectory, **not**
//!   gated: shared CI runners are too noisy);
//! - `"metrics"` — virtual-time serving metrics from the fixed smoke
//!   sweep (max QPS under SLO with/without pool offload, the gains,
//!   p99 TTFT). The simulator is deterministic, so these are
//!   bit-identical on every machine — `tools/bench_regression.py`
//!   fails CI when one regresses >15% vs `BENCH_baseline.json`. The
//!   same presets are asserted (more tightly) by
//!   `rust/tests/serving_scenarios.rs`, so a green test suite implies
//!   a green gate.
//!
//! Env hooks: `BENCH_SMOKE=1` shrinks the wall-clock workloads; the
//! gated metric sweep always runs the full fixed grid.

use hyperparallel::faults::{LinkDegrade, RetryPolicy};
use hyperparallel::serving::{
    agentic_comparison, agentic_scenario, autoscale_comparison, autoscale_crash_scenario,
    autoscale_slo, cluster_slo, crossover_comparison, crossover_scenario, max_qps_under_slo,
    rate_sweep, run_agentic_scenario, run_cluster_scenario, run_scenario, smoke_scenario,
    smoke_slo, ArrivalProcess, ClusterFabric, ClusterMode, ClusterReport, OperatingPoint,
    AUTOSCALE_MEAN_RATE, CLUSTER_RATES, SMOKE_RATES,
};
use hyperparallel::supernode::LinkTier;
use hyperparallel::util::bench::{run, section, smoke, to_json, BenchResult};
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::stats::fmt_secs;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    section("serving DES wall-clock (requests through batcher + KV pages)");
    let (rate, fleet, iters) = if smoke() { (40.0, 2, 3) } else { (80.0, 4, 10) };
    let poisson = smoke_scenario(rate, 0.2, fleet);
    let n_reqs = poisson.workload.generate(poisson.horizon).len();
    results.push(run(
        &format!("serve sim poisson {n_reqs} reqs fleet={fleet}"),
        1,
        iters,
        || {
            std::hint::black_box(run_scenario(&poisson).completed());
        },
    ));
    let mut bursty = smoke_scenario(rate, 0.2, fleet);
    bursty.workload.arrival = ArrivalProcess::Bursty {
        rate_on: rate * 3.0,
        rate_off: rate * 0.2,
        mean_on: 0.5,
        mean_off: 1.5,
    };
    results.push(run(
        &format!("serve sim bursty mmpp fleet={fleet}"),
        1,
        iters,
        || {
            std::hint::black_box(run_scenario(&bursty).completed());
        },
    ));
    let elastic = hyperparallel::serving::autoscale_scenario(ClusterFabric::Supernode, true);
    let n_elastic = elastic.workload.generate(elastic.horizon).len();
    results.push(run(
        &format!("serve sim elastic diurnal {n_elastic} reqs (warmup/drain/limbo)"),
        1,
        iters,
        || {
            std::hint::black_box(run_cluster_scenario(&elastic).completed());
        },
    ));
    let agentic = agentic_scenario(ClusterFabric::Supernode, true);
    let n_agentic = agentic.workload.generate(agentic.horizon).len();
    results.push(run(
        &format!("serve sim agentic multiturn {n_agentic} turns (radix prefix store)"),
        1,
        iters,
        || {
            std::hint::black_box(run_agentic_scenario(&agentic).completed());
        },
    ));

    section("SLO operating points (virtual time — deterministic, CI-gated)");
    let slo = smoke_slo();
    let sweep = |frac: f64| -> Vec<OperatingPoint> {
        rate_sweep(&smoke_scenario(SMOKE_RATES[0], frac, 2), &SMOKE_RATES, &slo)
    };
    let base_points = sweep(0.0);
    let off_points = sweep(0.2);
    for (name, points) in [("no-offload", &base_points), ("pool-offload", &off_points)] {
        for p in points.iter() {
            println!(
                "  {name:<12} rate {:>5.0}  qps {:>6.1}  p99 ttft {:>10}  p99 tpot {:>10}  \
                 peak ctx {:>6}  slo {}",
                p.rate,
                p.admitted_qps,
                fmt_secs(p.p99_ttft),
                fmt_secs(p.p99_tpot),
                p.peak_context_tokens,
                if p.attains_slo { "yes" } else { "no" }
            );
        }
    }
    let base_op = max_qps_under_slo(&base_points).expect("baseline attains at the lowest rate");
    let off_op = max_qps_under_slo(&off_points).expect("offload attains at the lowest rate");
    let qps_gain = off_op.rate / base_op.rate;
    let ctx_gain = off_op.peak_context_tokens as f64 / base_op.peak_context_tokens as f64;
    println!(
        "\n  max QPS under SLO: pool-offload {:.0} vs no-offload {:.0} ({qps_gain:.2}x QPS, \
         {ctx_gain:.2}x peak context)",
        off_op.rate, base_op.rate
    );

    let mut metrics = JsonObj::new();
    metrics.insert("serving.no_offload.max_qps_under_slo", Json::from(base_op.rate));
    metrics.insert("serving.pool_offload.max_qps_under_slo", Json::from(off_op.rate));
    metrics.insert("serving.offload_qps_gain", Json::from(qps_gain));
    metrics.insert("serving.offload_context_gain", Json::from(ctx_gain));
    metrics.insert("serving.pool_offload.p99_ttft_s", Json::from(off_op.p99_ttft));
    // p99 TTFT at a FIXED mid-grid rate: unlike the operating point's
    // p99 (which is <= the SLO by construction), this one can actually
    // regress, so it is the TTFT metric the baseline gates.
    metrics.insert(
        "serving.pool_offload.p99_ttft_at_fixed_rate_s",
        Json::from(off_points[4].p99_ttft),
    );
    metrics.insert(
        "serving.fixed_rate_qps",
        Json::from(off_points[4].rate),
    );
    metrics.insert("serving.pool_offload.goodput_qps", Json::from(off_op.goodput));
    metrics.insert(
        "serving.no_offload.peak_context_tokens",
        Json::from(base_op.peak_context_tokens),
    );
    metrics.insert(
        "serving.pool_offload.peak_context_tokens",
        Json::from(off_op.peak_context_tokens),
    );

    section("cluster crossover (virtual time — deterministic, CI-gated)");
    let x = crossover_comparison();
    println!(
        "  supernode: disaggregated {:.0} vs colocated {:.0} req/s ({:.2}x)",
        x.disagg_supernode.rate,
        x.colocated_supernode.rate,
        x.supernode_disagg_gain()
    );
    println!(
        "  legacy:    disaggregated {:.0} vs colocated {:.0} req/s (colocated {:.2}x ahead)",
        x.disagg_legacy.rate,
        x.colocated_legacy.rate,
        x.legacy_colocated_gain()
    );
    metrics.insert(
        "serving.cluster.colocated.max_qps_under_slo",
        Json::from(x.colocated_supernode.rate),
    );
    metrics.insert(
        "serving.cluster.supernode_disagg.max_qps_under_slo",
        Json::from(x.disagg_supernode.rate),
    );
    metrics.insert(
        "serving.cluster.legacy_disagg.max_qps_under_slo",
        Json::from(x.disagg_legacy.rate),
    );
    metrics.insert(
        "serving.cluster.supernode.disagg_qps_gain",
        Json::from(x.supernode_disagg_gain()),
    );
    metrics.insert(
        "serving.cluster.legacy.colocated_qps_gain",
        Json::from(x.legacy_colocated_gain()),
    );

    section("elastic autoscaling (virtual time — deterministic, CI-gated)");
    let aslo = autoscale_slo();
    let cmp = autoscale_comparison(ClusterFabric::Supernode);
    let static_op = cmp.static_report.operating_point(AUTOSCALE_MEAN_RATE, &aslo);
    let elastic_op = cmp.elastic_report.operating_point(AUTOSCALE_MEAN_RATE, &aslo);
    let saved = cmp.instance_seconds_saved();
    println!(
        "  static  peak: p99 ttft {:>10}  inst-sec {:>7.1}  slo {}",
        fmt_secs(static_op.p99_ttft),
        cmp.static_report.instance_seconds,
        if static_op.attains_slo { "yes" } else { "no" }
    );
    println!(
        "  elastic:      p99 ttft {:>10}  inst-sec {:>7.1}  ups {} downs {}  slo {}",
        fmt_secs(elastic_op.p99_ttft),
        cmp.elastic_report.instance_seconds,
        cmp.elastic_report.scale_ups,
        cmp.elastic_report.scale_downs,
        if elastic_op.attains_slo { "yes" } else { "no" }
    );
    println!("  instance-seconds saved: {:.1}% (gate >= 25%)", saved * 100.0);
    let crash_sc = autoscale_crash_scenario(ClusterFabric::Supernode);
    let submitted = crash_sc.workload.generate(crash_sc.horizon).len();
    let crash = run_cluster_scenario(&crash_sc);
    let crash_completed_frac = crash.completed() as f64 / submitted as f64;
    println!(
        "  crash run: {}/{} completed ({} requeued, {} rejected), p99 ttft {}",
        crash.completed(),
        submitted,
        crash.crash_requeues,
        crash.serving.rejected,
        fmt_secs(crash.serving.ttft_pct(99.0))
    );
    metrics.insert(
        "serving.autoscale.instance_hours_saved_frac",
        Json::from(saved),
    );
    metrics.insert(
        "serving.autoscale.elastic.p99_ttft_s",
        Json::from(elastic_op.p99_ttft),
    );
    metrics.insert(
        "serving.autoscale.static.p99_ttft_s",
        Json::from(static_op.p99_ttft),
    );
    metrics.insert(
        "serving.autoscale.crash_completed_frac",
        Json::from(crash_completed_frac),
    );
    metrics.insert(
        "serving.autoscale.crash.p99_ttft_s",
        Json::from(crash.serving.ttft_pct(99.0)),
    );

    section("goodput under fabric degradation (virtual time — deterministic, CI-gated)");
    // ISSUE 6: the disaggregated crossover preset with every non-local
    // tier degraded to 10% bandwidth / 10x latency over the middle half
    // of the arrival window, retry/hedging armed. The gate is coarse —
    // degradation must never *lose* requests (retries fall back to the
    // slow path, they never shed) — while the goodput ratio is archived
    // for the trajectory.
    let clean_sc = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
    let mut degr_sc = clean_sc.clone();
    for tier in [LinkTier::Board, LinkTier::Rack, LinkTier::CrossRack] {
        degr_sc.cluster.faults.link_windows.push(LinkDegrade {
            tier,
            start: 2.0,
            end: 6.0,
            bandwidth_scale: 0.1,
            latency_scale: 10.0,
        });
    }
    degr_sc.cluster.retry = Some(RetryPolicy::degraded_fabric());
    let degraded_submitted = degr_sc.workload.generate(degr_sc.horizon).len();
    let cslo = cluster_slo();
    let clean_rep = run_cluster_scenario(&clean_sc);
    let degr_rep = run_cluster_scenario(&degr_sc);
    let clean_op = clean_rep.operating_point(CLUSTER_RATES[0], &cslo);
    let degr_op = degr_rep.operating_point(CLUSTER_RATES[0], &cslo);
    let degraded_completed_frac = degr_rep.completed() as f64 / degraded_submitted as f64;
    let goodput_ratio = if clean_op.goodput > 0.0 {
        degr_op.goodput / clean_op.goodput
    } else {
        1.0
    };
    println!(
        "  degraded  {:>4}/{degraded_submitted} reqs  goodput {:>6.1} vs clean {:>6.1} \
         ({goodput_ratio:.2}x)  p99 ttft {:>10} vs {:>10}  retries {} hedged {}",
        degr_rep.completed(),
        degr_op.goodput,
        clean_op.goodput,
        fmt_secs(degr_op.p99_ttft),
        fmt_secs(clean_op.p99_ttft),
        degr_rep.retries_scheduled,
        degr_rep.hedged,
    );
    metrics.insert(
        "faults.degraded.completed_frac",
        Json::from(degraded_completed_frac),
    );
    metrics.insert("faults.degraded.goodput_qps", Json::from(degr_op.goodput));
    metrics.insert("faults.degraded.goodput_ratio", Json::from(goodput_ratio));
    metrics.insert(
        "faults.degraded.p99_ttft_s",
        Json::from(degr_op.p99_ttft),
    );
    metrics.insert(
        "faults.degraded.retries",
        Json::from(degr_rep.retries_scheduled as f64),
    );
    metrics.insert(
        "faults.degraded.hedged",
        Json::from(degr_rep.hedged as f64),
    );

    section("agentic prefix cache (virtual time — deterministic, CI-gated)");
    // ISSUE 7: every gated number flows through the same summary_kv
    // rows the reports print everywhere else — the gate and the
    // human-readable surfaces can never drift apart.
    let kv_of = |rep: &ClusterReport, key: &str| -> f64 {
        rep.summary_kv()
            .into_iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .unwrap_or_else(|| panic!("summary_kv misses {key}"))
    };
    let sn = agentic_comparison(ClusterFabric::Supernode);
    let lg = agentic_comparison(ClusterFabric::Legacy);
    for (fabric, s) in [("supernode", &sn), ("legacy", &lg)] {
        println!(
            "  {fabric:<9} cache-aware {:.0} vs cache-blind {:.0} req/s ({:.2}x)  hit rate \
             {:.3}  recomputed ratio {:.3}  fetch {}",
            s.aware.rate,
            s.blind.rate,
            s.qps_gain(),
            kv_of(&s.aware_report, "prefix_hit_rate"),
            kv_of(&s.aware_report, "tokens_recomputed_ratio"),
            fmt_secs(kv_of(&s.aware_report, "prefix_fetch_time")),
        );
    }
    println!(
        "  headline: {:.2}x on supernode (gate >= 1.3x), collapsing to {:.2}x on legacy",
        sn.qps_gain(),
        lg.qps_gain()
    );
    metrics.insert(
        "serving.prefix.supernode.aware.max_qps_under_slo",
        Json::from(sn.aware.rate),
    );
    metrics.insert(
        "serving.prefix.supernode.blind.max_qps_under_slo",
        Json::from(sn.blind.rate),
    );
    metrics.insert("serving.prefix.supernode.qps_gain", Json::from(sn.qps_gain()));
    metrics.insert(
        "serving.prefix.supernode.tokens_recomputed_ratio",
        Json::from(kv_of(&sn.aware_report, "tokens_recomputed_ratio")),
    );
    metrics.insert(
        "serving.prefix.supernode.hit_rate",
        Json::from(kv_of(&sn.aware_report, "prefix_hit_rate")),
    );
    metrics.insert("serving.prefix.legacy.qps_gain", Json::from(lg.qps_gain()));

    // Combined artifact: wall-clock benches + gated virtual-time
    // metrics. Written directly (not via util::bench::maybe_write_json)
    // because the gate needs the "metrics" object alongside the bench
    // array.
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = JsonObj::new();
        root.insert("benches", to_json(&results));
        root.insert("metrics", Json::Obj(metrics));
        match std::fs::write(&path, Json::Obj(root).pretty()) {
            Ok(()) => println!("\nbench json written to {path}"),
            Err(e) => {
                eprintln!("\nbench json write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
