//! Runtime hot path — PJRT execute latency and the L3 inner loops.
//!
//! Not a paper table: this is the §Perf harness for the performance
//! pass (EXPERIMENTS.md §Perf). Measures artifact execution latency,
//! literal marshalling, the real all-reduce, and the simulator's
//! event-loop throughput.

use hyperparallel::collectives::real::{all_reduce_mean, all_reduce_mean_tree};
use hyperparallel::runtime::{literal_f32, literal_i32, Runtime};
use hyperparallel::sim::Engine;
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::rng::Rng;

fn main() {
    section("PJRT hot path (requires `make artifacts`)");
    match Runtime::cpu("artifacts") {
        Ok(mut rt) => {
            if rt.load("kernel_demo").is_ok() {
                let mut rng = Rng::new(1);
                let x: Vec<f32> = (0..64 * 32).map(|_| rng.normal() as f32).collect();
                let w1: Vec<f32> = (0..4 * 32 * 64).map(|_| rng.normal() as f32 * 0.1).collect();
                let w2: Vec<f32> = (0..4 * 64 * 32).map(|_| rng.normal() as f32 * 0.1).collect();
                let assign: Vec<i32> = (0..64).map(|_| rng.below(4) as i32).collect();
                run("kernel_demo execute (64x32 MoE FFN)", 3, 30, || {
                    let inputs = [
                        literal_f32(&[64, 32], &x).unwrap(),
                        literal_f32(&[4, 32, 64], &w1).unwrap(),
                        literal_f32(&[4, 64, 32], &w2).unwrap(),
                        literal_i32(&[64], &assign).unwrap(),
                    ];
                    std::hint::black_box(rt.execute("kernel_demo", &inputs).unwrap());
                });
                run("literal marshalling only (same payload)", 3, 100, || {
                    std::hint::black_box(literal_f32(&[4, 32, 64], &w1).unwrap());
                });
            }
        }
        Err(e) => println!("  pjrt unavailable: {e} (run `make artifacts`)"),
    }

    section("real all-reduce (DP gradient sync)");
    let mk = |p: usize, n: usize| -> Vec<Vec<f32>> {
        let mut rng = Rng::new(7);
        (0..p)
            .map(|_| (0..n).map(|_| rng.next_f32()).collect())
            .collect()
    };
    for (p, n) in [(4, 1 << 16), (4, 1 << 20), (8, 1 << 20)] {
        let base = mk(p, n);
        run(&format!("all_reduce_mean naive  p={p} n={n}"), 2, 20, || {
            let mut ranks = base.clone();
            all_reduce_mean(&mut ranks);
            std::hint::black_box(ranks[0][0]);
        });
        run(&format!("all_reduce_mean tree   p={p} n={n}"), 2, 20, || {
            let mut ranks = base.clone();
            all_reduce_mean_tree(&mut ranks);
            std::hint::black_box(ranks[0][0]);
        });
    }

    section("simulator event-loop throughput");
    for tasks in [1_000, 10_000, 100_000] {
        run(&format!("sim run, {tasks} chained tasks on 16 resources"), 2, 10, || {
            let mut e = Engine::new();
            let rs: Vec<_> = (0..16).map(|i| e.add_resource(format!("r{i}"))).collect();
            let mut prev = None;
            for i in 0..tasks {
                let deps: Vec<_> = prev.iter().copied().collect();
                prev = Some(e.add_task(rs[i % 16], 1e-6, &deps, 0));
            }
            std::hint::black_box(e.run().makespan);
        });
    }
}
