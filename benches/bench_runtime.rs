//! Runtime hot path — PJRT execute latency and the L3 inner loops.
//!
//! Not a paper table: this is the §Perf harness for the performance
//! pass (EXPERIMENTS.md §Perf). Measures artifact execution latency,
//! literal marshalling, the real all-reduce, the simulator's
//! event-loop throughput, the indexed `SimResult` metric queries, and
//! the parallel scenario sweep.
//!
//! Env hooks: `BENCH_SMOKE=1` shrinks workloads to CI size;
//! `BENCH_JSON=<path>` dumps the result set as JSON (the cross-PR perf
//! trajectory artifact).

use hyperparallel::collectives::real::{all_reduce_mean, all_reduce_mean_tree};
use hyperparallel::hypermpmd::{chunk_sweep, schedule_moe_stack, MoeLayerLoad};
use hyperparallel::runtime::{literal_f32, literal_i32, Runtime};
use hyperparallel::serving::{crossover_scenario, run_cluster_scenario, ClusterFabric, ClusterMode};
use hyperparallel::sim::{Engine, ResourceId, TaskId, TraceMode};
use hyperparallel::util::bench::{run, section, smoke, to_json, BenchResult};
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::rng::Rng;

/// The supernode-scale DES workload from the perf acceptance bar:
/// `resources` stream resources × `tasks` tasks, per-resource FIFO
/// chains with periodic cross-resource dependencies (comm-like edges).
/// Fully deterministic.
fn build_supernode_workload(resources: usize, tasks: usize) -> Engine {
    let mut e = Engine::new();
    let rs: Vec<_> = (0..resources)
        .map(|i| e.add_resource(format!("r{i}")))
        .collect();
    let mut prev: Vec<Option<TaskId>> = vec![None; resources];
    let mut deps: Vec<TaskId> = Vec::with_capacity(2);
    for i in 0..tasks {
        let r = i % resources;
        deps.clear();
        if let Some(p) = prev[r] {
            deps.push(p);
        }
        // periodic cross-resource edge to an earlier task
        if i >= resources && i % 7 == 0 {
            deps.push(TaskId(i - resources + (i % 3)));
        }
        let dur = 1e-6 * (1.0 + (i % 13) as f64);
        let tag = (i % 4) as u64;
        prev[r] = Some(e.add_task(rs[r], dur, &deps, tag));
    }
    e
}

/// A masking-evaluation-style metric block: the ~12 busy/overlap
/// queries `hypermpmd::intra` issues per evaluation, over several
/// stream groups. O(1)/allocation-free on the indexed result.
fn metric_block(res: &hyperparallel::sim::SimResult, resources: usize) -> f64 {
    let mut acc = 0.0;
    for g in 0..4 {
        let a = ResourceId((g * 17) % resources);
        let b = ResourceId((g * 17 + 1) % resources);
        acc += res.busy_time(a) + res.busy_time(b);
        acc += res.utilization(a) + res.bubble_ratio(b);
        acc += res.overlap_time(a, b) + res.overlap_ratio(b, a);
        acc += res.tagged_count(g as u64) as f64;
    }
    acc
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    section("PJRT hot path (requires `make artifacts`)");
    match Runtime::cpu("artifacts") {
        Ok(mut rt) => {
            if rt.load("kernel_demo").is_ok() {
                let mut rng = Rng::new(1);
                let x: Vec<f32> = (0..64 * 32).map(|_| rng.normal() as f32).collect();
                let w1: Vec<f32> = (0..4 * 32 * 64).map(|_| rng.normal() as f32 * 0.1).collect();
                let w2: Vec<f32> = (0..4 * 64 * 32).map(|_| rng.normal() as f32 * 0.1).collect();
                let assign: Vec<i32> = (0..64).map(|_| rng.below(4) as i32).collect();
                results.push(run("kernel_demo execute (64x32 MoE FFN)", 3, 30, || {
                    let inputs = [
                        literal_f32(&[64, 32], &x).unwrap(),
                        literal_f32(&[4, 32, 64], &w1).unwrap(),
                        literal_f32(&[4, 64, 32], &w2).unwrap(),
                        literal_i32(&[64], &assign).unwrap(),
                    ];
                    std::hint::black_box(rt.execute("kernel_demo", &inputs).unwrap());
                }));
                results.push(run("literal marshalling only (same payload)", 3, 100, || {
                    std::hint::black_box(literal_f32(&[4, 32, 64], &w1).unwrap());
                }));
            }
        }
        Err(e) => println!("  pjrt unavailable: {e} (run `make artifacts`)"),
    }

    section("real all-reduce (DP gradient sync)");
    let mk = |p: usize, n: usize| -> Vec<Vec<f32>> {
        let mut rng = Rng::new(7);
        (0..p)
            .map(|_| (0..n).map(|_| rng.next_f32()).collect())
            .collect()
    };
    let ar_cases: &[(usize, usize)] = if smoke() {
        &[(4, 1 << 16)]
    } else {
        &[(4, 1 << 16), (4, 1 << 20), (8, 1 << 20)]
    };
    for &(p, n) in ar_cases {
        let base = mk(p, n);
        results.push(run(&format!("all_reduce_mean naive  p={p} n={n}"), 2, 20, || {
            let mut ranks = base.clone();
            all_reduce_mean(&mut ranks);
            std::hint::black_box(ranks[0][0]);
        }));
        results.push(run(&format!("all_reduce_mean tree   p={p} n={n}"), 2, 20, || {
            let mut ranks = base.clone();
            all_reduce_mean_tree(&mut ranks);
            std::hint::black_box(ranks[0][0]);
        }));
    }

    section("simulator event-loop throughput");
    let chain_sizes: &[usize] = if smoke() {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    for &tasks in chain_sizes {
        results.push(run(
            &format!("sim run, {tasks} chained tasks on 16 resources"),
            2,
            10,
            || {
                let mut e = Engine::new();
                let rs: Vec<_> = (0..16).map(|i| e.add_resource(format!("r{i}"))).collect();
                let mut prev = None;
                for i in 0..tasks {
                    let deps: Vec<_> = prev.iter().copied().collect();
                    prev = Some(e.add_task(rs[i % 16], 1e-6, &deps, 0));
                }
                std::hint::black_box(e.run().makespan);
            },
        ));
    }

    section("indexed SimResult — supernode-scale workload (perf bar: ≥2x vs scan-based)");
    let (n_res, n_tasks, iters) = if smoke() {
        (128, 10_000, 3)
    } else {
        (1_000, 100_000, 10)
    };
    // (a) build + run + masking-style metric evaluation: the acceptance
    // workload. The old SimResult re-scanned all N intervals (with a
    // fresh Vec<&Interval> per overlap call) for every one of the ~28
    // queries below; the index answers them in O(1)/two-pointer.
    results.push(run(
        &format!("sim run + metric eval, {n_tasks} tasks / {n_res} resources"),
        1,
        iters,
        || {
            let mut e = build_supernode_workload(n_res, n_tasks);
            let res = e.run();
            std::hint::black_box(metric_block(&res, n_res));
        },
    ));
    // (b) metric queries alone on a prebuilt result — the per-query
    // cost the masking scheduler pays ~12x per evaluation
    let mut e = build_supernode_workload(n_res, n_tasks);
    let res = e.run();
    results.push(run(
        &format!("metric eval alone, {n_tasks}-interval result"),
        2,
        iters.max(20),
        || {
            std::hint::black_box(metric_block(&res, n_res));
        },
    ));

    section("streaming trace sink — event throughput + bounded buffering (CI-gated)");
    // (a) wall-clock engine-event throughput under the streaming sink:
    // the city-scale feasibility number. Gated very generously (the
    // virtual-time metrics below are the tight gates); its job is to
    // catch an order-of-magnitude event-loop regression, not noise.
    let (s_res, s_tasks, s_iters) = if smoke() {
        (128, 50_000, 5)
    } else {
        (1_000, 500_000, 10)
    };
    let r_stream = run(
        &format!("sim run streaming, {s_tasks} tasks / {s_res} resources"),
        1,
        s_iters,
        || {
            let mut e = build_supernode_workload(s_res, s_tasks);
            std::hint::black_box(e.run_trace(TraceMode::Streaming).makespan());
        },
    );
    let events_per_sec = s_tasks as f64 / r_stream.min_s;
    println!("  sim.events_per_sec = {events_per_sec:.3e} (min of {} iters, incl. build)", r_stream.iters);
    results.push(r_stream);
    // (b) deterministic: a streaming cluster run buffers only the
    // concurrently-open intervals — bounded by the instance count, no
    // matter how many events the run produced.
    let mut ssc = crossover_scenario(ClusterFabric::Supernode, ClusterMode::Disaggregated);
    ssc.cluster.trace_mode = TraceMode::Streaming;
    let srep = run_cluster_scenario(&ssc);
    let peak_buffered = srep.serving.trace.peak_buffered();
    let total_intervals = srep.serving.trace.interval_count();
    println!(
        "  streaming cluster crossover: {total_intervals} intervals folded, peak {peak_buffered} \
         buffered ({} instances)",
        ssc.cluster.instances.len()
    );

    let mut metrics = JsonObj::new();
    metrics.insert("sim.events_per_sec", Json::from(events_per_sec));
    metrics.insert(
        "sim.streaming.peak_buffered_intervals",
        Json::from(peak_buffered),
    );
    metrics.insert(
        "sim.streaming.total_intervals",
        Json::from(total_intervals as f64),
    );

    section("parallel scenario sweep (sim::sweep over std::thread::scope)");
    let load = MoeLayerLoad::deepseek_like();
    let chunks: Vec<usize> = if smoke() {
        vec![2, 4, 8, 16]
    } else {
        vec![1, 2, 4, 8, 12, 16, 24, 32]
    };
    let layers = if smoke() { 4 } else { 8 };
    results.push(run(
        &format!("chunk sweep x{} sequential", chunks.len()),
        1,
        5,
        || {
            for &c in &chunks {
                std::hint::black_box(schedule_moe_stack(load, layers, c, true).masking_ratio);
            }
        },
    ));
    results.push(run(
        &format!("chunk sweep x{} sim::sweep", chunks.len()),
        1,
        5,
        || {
            std::hint::black_box(chunk_sweep(load, layers, &chunks, true).len());
        },
    ));

    // Combined artifact: wall-clock benches + the gated metrics above
    // (same shape as bench_serving's, so tools/bench_regression.py can
    // merge the "metrics" objects across bench binaries).
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = JsonObj::new();
        root.insert("benches", to_json(&results));
        root.insert("metrics", Json::Obj(metrics));
        match std::fs::write(&path, Json::Obj(root).pretty()) {
            Ok(()) => println!("\nbench json written to {path}"),
            Err(e) => {
                eprintln!("\nbench json write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
