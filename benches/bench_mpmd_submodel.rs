//! E8 — inter-sub-model concurrency balancing (paper §3.3b, Fig 4b).
//!
//! Paper: dynamic sub-model scheduling eliminates the 10–40% pipeline
//! bubbles of heterogeneous omni-modal models, for ~15% overall
//! training gain. We regenerate the comparison and sweep heterogeneity.

use hyperparallel::hypermpmd::{schedule_dynamic, schedule_static, OmniModalWorkload, SubModule};
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E8: omni-modal bubbles — paper: 10-40% bubbles, ~15% gain");
    let w = OmniModalWorkload::paper_shape(16);
    let stat = schedule_static(&w);
    let dyn_ = schedule_dynamic(&w, w.modules.len());

    let rows = vec![
        vec![
            "pipeline bubbles".into(),
            "10-40%".into(),
            "~0".into(),
            format!("{:.1}%", stat.bubble_ratio * 100.0),
            format!("{:.1}%", dyn_.bubble_ratio * 100.0),
        ],
        vec![
            "step time".into(),
            "-".into(),
            "~15% faster".into(),
            fmt_secs(stat.makespan),
            format!(
                "{} ({:+.1}%)",
                fmt_secs(dyn_.makespan),
                (stat.makespan / dyn_.makespan - 1.0) * 100.0
            ),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["metric", "paper static", "paper dynamic", "ours static", "ours dynamic"],
            &rows
        )
    );

    section("heterogeneity sweep (encoder imbalance -> static bubbles -> gain)");
    println!(
        "{:>12} {:>14} {:>14} {:>8}",
        "imbalance", "static bubbles", "dyn bubbles", "gain"
    );
    for spread in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let base = 60e-3;
        let w = OmniModalWorkload {
            modules: vec![
                SubModule { name: "enc-a".into(), time_per_microbatch: base * (1.0 - spread), inputs: vec![] },
                SubModule { name: "enc-b".into(), time_per_microbatch: base * (1.0 + spread), inputs: vec![] },
                SubModule { name: "enc-c".into(), time_per_microbatch: base, inputs: vec![] },
                SubModule { name: "fusion".into(), time_per_microbatch: base * 0.7, inputs: vec![0, 1, 2] },
                SubModule { name: "decoder".into(), time_per_microbatch: base * (1.0 + spread), inputs: vec![3] },
            ],
            microbatches: 16,
        };
        let s = schedule_static(&w);
        let d = schedule_dynamic(&w, 5);
        println!(
            "{spread:>12.1} {:>13.1}% {:>13.1}% {:>7.1}%",
            s.bubble_ratio * 100.0,
            d.bubble_ratio * 100.0,
            (s.makespan / d.makespan - 1.0) * 100.0
        );
    }

    section("microbatch-count sweep");
    println!("{:>6} {:>14} {:>8}", "mb", "static bubbles", "gain");
    for mb in [4, 8, 16, 32, 64] {
        let w = OmniModalWorkload::paper_shape(mb);
        let s = schedule_static(&w);
        let d = schedule_dynamic(&w, w.modules.len());
        println!(
            "{mb:>6} {:>13.1}% {:>7.1}%",
            s.bubble_ratio * 100.0,
            (s.makespan / d.makespan - 1.0) * 100.0
        );
    }

    section("harness timing");
    let w = OmniModalWorkload::paper_shape(16);
    run("dynamic schedule (5 modules x 16 mb)", 2, 50, || {
        std::hint::black_box(schedule_dynamic(&w, 5).makespan);
    });
}
