//! E8 — inter-sub-model concurrency balancing (paper §3.3b, Fig 4b).
//!
//! Paper: dynamic sub-model scheduling eliminates the 10–40% pipeline
//! bubbles of heterogeneous omni-modal models, for ~15% overall
//! training gain. We regenerate the comparison and sweep heterogeneity.

use hyperparallel::hypermpmd::{
    microbatch_sweep, schedule_dynamic, schedule_static, OmniModalWorkload, SubModule,
};
use hyperparallel::sim::SweepSpec;
use hyperparallel::trainer::{gpipe_sweep, one_f_one_b_bubble};
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E8: omni-modal bubbles — paper: 10-40% bubbles, ~15% gain");
    let w = OmniModalWorkload::paper_shape(16);
    let stat = schedule_static(&w);
    let dyn_ = schedule_dynamic(&w, w.modules.len());

    let rows = vec![
        vec![
            "pipeline bubbles".into(),
            "10-40%".into(),
            "~0".into(),
            format!("{:.1}%", stat.bubble_ratio * 100.0),
            format!("{:.1}%", dyn_.bubble_ratio * 100.0),
        ],
        vec![
            "step time".into(),
            "-".into(),
            "~15% faster".into(),
            fmt_secs(stat.makespan),
            format!(
                "{} ({:+.1}%)",
                fmt_secs(dyn_.makespan),
                (stat.makespan / dyn_.makespan - 1.0) * 100.0
            ),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["metric", "paper static", "paper dynamic", "ours static", "ours dynamic"],
            &rows
        )
    );

    section("heterogeneity sweep (encoder imbalance -> static bubbles -> gain)");
    println!(
        "{:>12} {:>14} {:>14} {:>8}",
        "imbalance", "static bubbles", "dyn bubbles", "gain"
    );
    let spreads = SweepSpec::over("imbalance", vec![0.0, 0.2, 0.4, 0.6, 0.8]).run(|&spread| {
        let base = 60e-3;
        let w = OmniModalWorkload {
            modules: vec![
                SubModule { name: "enc-a".into(), time_per_microbatch: base * (1.0 - spread), inputs: vec![] },
                SubModule { name: "enc-b".into(), time_per_microbatch: base * (1.0 + spread), inputs: vec![] },
                SubModule { name: "enc-c".into(), time_per_microbatch: base, inputs: vec![] },
                SubModule { name: "fusion".into(), time_per_microbatch: base * 0.7, inputs: vec![0, 1, 2] },
                SubModule { name: "decoder".into(), time_per_microbatch: base * (1.0 + spread), inputs: vec![3] },
            ],
            microbatches: 16,
        };
        (schedule_static(&w), schedule_dynamic(&w, 5))
    });
    for row in spreads {
        let (s, d) = row.value;
        println!(
            "{:>12.1} {:>13.1}% {:>13.1}% {:>7.1}%",
            row.point,
            s.bubble_ratio * 100.0,
            d.bubble_ratio * 100.0,
            (s.makespan / d.makespan - 1.0) * 100.0
        );
    }

    section("microbatch-count sweep (parallel via sim::sweep)");
    println!("{:>6} {:>14} {:>8}", "mb", "static bubbles", "gain");
    for (mb, s, d) in microbatch_sweep(OmniModalWorkload::paper_shape, &[4, 8, 16, 32, 64]) {
        println!(
            "{mb:>6} {:>13.1}% {:>7.1}%",
            s.bubble_ratio * 100.0,
            (s.makespan / d.makespan - 1.0) * 100.0
        );
    }

    section("GPipe reference (the SPMD+PP bubble model E8 compares against)");
    let stages = vec![60e-3f64, 75e-3, 65e-3, 80e-3];
    let counts = [4usize, 8, 16, 32];
    println!("{:>6} {:>12} {:>12}", "mb", "sim bubbles", "analytic");
    for (&mb, r) in counts.iter().zip(&gpipe_sweep(&stages, &counts)) {
        println!(
            "{mb:>6} {:>11.1}% {:>11.1}%",
            r.bubble_ratio * 100.0,
            one_f_one_b_bubble(stages.len(), mb) * 100.0
        );
    }

    section("harness timing");
    let w = OmniModalWorkload::paper_shape(16);
    run("dynamic schedule (5 modules x 16 mb)", 2, 50, || {
        std::hint::black_box(schedule_dynamic(&w, 5).makespan);
    });
}
