//! Fleet heterogeneity bench (ISSUE 9) — wall-clock throughput of the
//! fleet-priced two-tenant DES, plus the deterministic virtual-time
//! heterogeneity metrics CI gates on.
//!
//! Two result classes go into `BENCH_fleet.json` (`BENCH_JSON=<path>`):
//! `"benches"` (wall-clock timings, archived, not gated) and
//! `"metrics"` — the three checked-in seed-42 heterogeneity scenarios,
//! each compared heterogeneity-aware vs naive-uniform on identical
//! hardware:
//!
//!   - mixed generations: aware/naive steps-by-deadline gain
//!     (calibrated 1.33×, gated at 1.15) and the aware/naive reshard
//!     ratio (the crossing rule only pays the DCN when it's worth it);
//!   - slow rack: the straggler-aware partitioning gain (calibrated
//!     1.67×, gated at 1.25);
//!   - cross-supernode prefill: naive/aware KV-transfer-seconds ratio
//!     (calibrated 3.9×, gated at 2.0).
//!
//! The simulators are deterministic, so the metrics are bit-identical
//! on every machine; `tools/bench_regression.py` gates them against
//! the `fleet.*` entries of `BENCH_baseline.json`. The same presets
//! are asserted (more tightly) by `rust/tests/fleet_scenarios.rs`, so
//! a green test suite implies a green gate.

use hyperparallel::hypermpmd::coschedule::{
    cosched_slo, fleet_cosched_scenario, run_cosched, CoschedReport, FleetScenario,
};
use hyperparallel::serving::{fleet_prefill_scenario, run_cluster_scenario, AUTOSCALE_MEAN_RATE};
use hyperparallel::util::bench::{run, section, smoke, to_json, BenchResult};
use hyperparallel::util::json::{Json, JsonObj};
use hyperparallel::util::stats::fmt_secs;

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    section("fleet co-scheduled DES wall-clock (64-device mixed fleet)");
    let iters = if smoke() { 2 } else { 5 };
    let sc = fleet_cosched_scenario(FleetScenario::MixedGenerations, true);
    let n_reqs = sc.workload.generate(sc.horizon).len();
    results.push(run(
        &format!("fleet cosched sim mixed {n_reqs} reqs + weighted trainer"),
        1,
        iters,
        || {
            std::hint::black_box(run_cosched(&sc).train.steps);
        },
    ));
    let psc = fleet_prefill_scenario(true);
    results.push(run(
        "fleet prefill sim dual-supernode aware placement",
        1,
        iters,
        || {
            std::hint::black_box(run_cluster_scenario(&psc).kv_migrations);
        },
    ));

    section("heterogeneity gates (virtual time — deterministic, CI-gated)");
    let slo = cosched_slo();
    let mut metrics = JsonObj::new();
    let cell = |which: FleetScenario, aware: bool| -> CoschedReport {
        run_cosched(&fleet_cosched_scenario(which, aware))
    };
    for (name, which) in [
        ("mixed", FleetScenario::MixedGenerations),
        ("slow_rack", FleetScenario::SlowRack),
    ] {
        let aware = cell(which, true);
        let naive = cell(which, false);
        let gain = aware.train.steps_by_deadline as f64 / naive.train.steps_by_deadline as f64;
        let op = aware.serving.operating_point(AUTOSCALE_MEAN_RATE, &slo);
        println!(
            "  {name:<10} aware {:>3} vs naive {:>3} steps ({gain:.2}x)  \
             serving p99 ttft {:>10}  reshards {:>3} ({:>8} on fabric)  slo {}",
            aware.train.steps_by_deadline,
            naive.train.steps_by_deadline,
            fmt_secs(op.p99_ttft),
            aware.train.reshards,
            fmt_secs(aware.train.reshard_seconds),
            if op.attains_slo { "yes" } else { "no" }
        );
        metrics.insert(format!("fleet.{name}.steps_gain"), Json::from(gain));
        metrics.insert(
            format!("fleet.{name}.serving_p99_ttft_s"),
            Json::from(op.p99_ttft),
        );
        // archived (not gated): the raw per-cell trajectory
        metrics.insert(
            format!("fleet.{name}.steps_by_deadline"),
            Json::from(aware.train.steps_by_deadline as f64),
        );
        metrics.insert(
            format!("fleet.{name}.naive_steps_by_deadline"),
            Json::from(naive.train.steps_by_deadline as f64),
        );
        metrics.insert(
            format!("fleet.{name}.peak_devices"),
            Json::from(aware.train.peak_devices as f64),
        );
        if which == FleetScenario::MixedGenerations {
            // the crossing rule: the aware trainer's inter-supernode
            // reshard bill must stay at or below the blind harvester's
            let ratio = aware.train.reshard_seconds / naive.train.reshard_seconds;
            println!(
                "  {name:<10} reshard bill aware {:>8} vs naive {:>8} ({ratio:.2}x)",
                fmt_secs(aware.train.reshard_seconds),
                fmt_secs(naive.train.reshard_seconds),
            );
            metrics.insert(
                "fleet.mixed.reshard_seconds",
                Json::from(aware.train.reshard_seconds),
            );
            metrics.insert("fleet.mixed.reshard_ratio", Json::from(ratio));
        }
    }

    section("cross-supernode prefill (virtual time — deterministic, CI-gated)");
    let aware = run_cluster_scenario(&fleet_prefill_scenario(true));
    let naive = run_cluster_scenario(&fleet_prefill_scenario(false));
    let xfer_ratio = naive.kv_xfer_time / aware.kv_xfer_time;
    println!(
        "  per-supernode pipelines: {:>4} reqs, {:>3} migrations, kv xfer {:>8}",
        aware.completed(),
        aware.kv_migrations,
        fmt_secs(aware.kv_xfer_time),
    );
    println!(
        "  role-per-supernode:      {:>4} reqs, {:>3} migrations, kv xfer {:>8}  \
         ({xfer_ratio:.2}x the aware bill)",
        naive.completed(),
        naive.kv_migrations,
        fmt_secs(naive.kv_xfer_time),
    );
    metrics.insert("fleet.prefill.xfer_ratio", Json::from(xfer_ratio));
    // archived (not gated)
    metrics.insert(
        "fleet.prefill.aware_kv_xfer_s",
        Json::from(aware.kv_xfer_time),
    );
    metrics.insert(
        "fleet.prefill.naive_kv_xfer_s",
        Json::from(naive.kv_xfer_time),
    );
    metrics.insert(
        "fleet.prefill.kv_migrations",
        Json::from(aware.kv_migrations as f64),
    );

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = JsonObj::new();
        root.insert("benches", to_json(&results));
        root.insert("metrics", Json::Obj(metrics));
        match std::fs::write(&path, Json::Obj(root).pretty()) {
            Ok(()) => println!("\nbench json written to {path}"),
            Err(e) => {
                eprintln!("\nbench json write to {path} failed: {e}");
                std::process::exit(1);
            }
        }
    }
}
