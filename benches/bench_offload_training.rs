//! E5 — HyperOffload training (paper §3.2).
//!
//! Paper: Llama-8B iteration time 5.2 s → 4.08 s (~20% / 1.27×) under
//! HyperOffload, and the required model-parallel degree collapses from
//! ND-SPMD to 1D-DP. We regenerate the comparison on the simulated
//! substrate and additionally sweep prefetch lookahead and pool fabric.

use hyperparallel::baselines::{offload_policy_comparison, zero_offload_step};
use hyperparallel::hyperoffload::OffloadPolicy;
use hyperparallel::memory::TransferEngine;
use hyperparallel::sim::SweepSpec;
use hyperparallel::trainer::scenarios::OffloadTrainingScenario;
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E5: HyperOffload training — paper Table (5.2s -> 4.08s, 1.27x)");
    let s = OffloadTrainingScenario::llama8b();

    let base = zero_offload_step(&s);
    let hyper = s.hyperoffload_step(2);
    let policy = OffloadPolicy::new(s.topo.devices[0].spec.hbm_bytes);
    let (mp_without, mp_with) = policy.min_model_parallel(&s.model.train_state());

    let rows = vec![
        vec![
            "step time".into(),
            "5.2 s".into(),
            "4.08 s (1.27x)".into(),
            fmt_secs(base),
            format!("{} ({:.2}x)", fmt_secs(hyper), base / hyper),
        ],
        vec![
            "model-parallel degree".into(),
            "ND-SPMD".into(),
            "1D-DP".into(),
            format!("tp*pp >= {mp_without}"),
            format!("tp*pp = {mp_with}"),
        ],
    ];
    print!(
        "{}",
        render_table(
            &["metric", "paper base", "paper hyper", "ours base", "ours hyper"],
            &rows
        )
    );

    section("lookahead sweep (pipeline depth of the multi-level cache, parallel)");
    for (k, t) in s.lookahead_sweep(&[1, 2, 3, 4]) {
        println!("  lookahead {k}: {}", fmt_secs(t));
    }

    section("policy comparison (all baselines, parallel via sim::sweep)");
    for (name, t) in offload_policy_comparison(&s) {
        match t {
            Some(t) => println!("  {name:<32} {}", fmt_secs(t)),
            None => println!("  {name:<32} (no memory-feasible plan)"),
        }
    }

    section("fabric sweep (same schedule, different pool link)");
    let cases: Vec<(String, (usize, TransferEngine))> = vec![
        ("pcie-sync (ZeRO-Offload)".into(), (1, TransferEngine::legacy_pcie())),
        ("pcie-pipe".into(), (2, TransferEngine::legacy_pcie())),
        ("ub-sync".into(), (1, TransferEngine::supernode())),
        ("ub-pipe (HyperOffload)".into(), (2, TransferEngine::supernode())),
    ];
    let rows = SweepSpec::with_labels("pool_link", cases).run(|case| s.step_time(case.0, case.1));
    for row in rows {
        println!("  {:<38} {}", row.label, fmt_secs(row.value));
    }

    section("harness timing (simulation cost itself)");
    run("simulate one llama8b offload step", 2, 10, || {
        std::hint::black_box(s.hyperoffload_step(2));
    });
}
