//! E6 — HyperOffload inference (paper §3.2).
//!
//! Paper: under identical latency constraints, max supported context
//! grows 71K → 123K (+70%). We regenerate the operating point and sweep
//! the SLO and the pool bandwidth.

use hyperparallel::hyperoffload::kvcache::{ContextPlanner, KvCacheConfig, PagedKvCache};
use hyperparallel::util::bench::{run, section};
use hyperparallel::util::stats::{fmt_secs, render_table};

fn main() {
    section("E6: HyperOffload inference — context at identical latency");
    let cfg = KvCacheConfig::llama8b_910c();
    let slo = ContextPlanner::baseline_latency(&cfg);
    let base = ContextPlanner::max_context_baseline(&cfg, slo);
    let (with, frac) = ContextPlanner::max_context_offload(&cfg, slo);

    let rows = vec![vec![
        "max context".into(),
        "71K".into(),
        "123K (+70%)".into(),
        format!("{base}"),
        format!("{with} ({:+.0}%)", (with as f64 / base as f64 - 1.0) * 100.0),
    ]];
    print!(
        "{}",
        render_table(
            &["metric", "paper base", "paper hyper", "ours base", "ours hyper"],
            &rows
        )
    );
    println!("(weight fraction streamed from pool at the optimum: {frac:.2})");

    section("SLO sweep (figure series: achievable context vs latency budget)");
    println!("{:>14} {:>12} {:>14} {:>8}", "SLO", "baseline", "hyperoffload", "gain");
    for mult in [0.6, 0.8, 1.0, 1.2, 1.5, 2.0] {
        let s = slo * mult;
        let b = ContextPlanner::max_context_baseline(&cfg, s);
        let (w, _) = ContextPlanner::max_context_offload(&cfg, s);
        println!(
            "{:>14} {b:>12} {w:>14} {:>7.0}%",
            fmt_secs(s),
            (w as f64 / b.max(1) as f64 - 1.0) * 100.0
        );
    }

    section("pool-bandwidth sweep (supernode UB vs legacy PCIe pools)");
    println!("{:>14} {:>14} {:>8}", "pool bw", "max context", "gain");
    for bw in [25e9, 64e9, 128e9, 200e9, 392e9, 784e9] {
        let mut c = cfg.clone();
        c.pool_bw = bw;
        let (w, _) = ContextPlanner::max_context_offload(&c, slo);
        println!(
            "{:>11} GB/s {w:>14} {:>7.0}%",
            (bw / 1e9) as u64,
            (w as f64 / base as f64 - 1.0) * 100.0
        );
    }

    section("paged-cache mechanics (page churn at 123K tokens)");
    run("append 123K tokens through the paged cache", 1, 5, || {
        let mut cache = PagedKvCache::new(cfg.clone(), frac);
        for _ in 0..123_000 {
            cache.append_token();
        }
        std::hint::black_box(cache.pages_swapped_out);
    });
}
